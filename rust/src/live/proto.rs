//! Typed service boundary + wire protocol for the live store.
//!
//! This module carves the monolithic [`LiveStore`] along two explicit
//! service surfaces, each transport-agnostic:
//!
//! * [`NodeService`] — one storage node's chunk store: the
//!   [`ChunkBackend`] operations plus recovery info, expressed as the
//!   exhaustive [`NodeRequest`] / [`NodeResponse`] enums. Implemented
//!   by [`NodeHost`] (a daemon's backend) and consumed remotely by
//!   [`super::rpc::RemoteBackend`].
//! * [`ManagerService`] — the manager/metadata surface the engine,
//!   scenario harness, and CLI drive: file writes/reads, attributes,
//!   placement queries, churn, and counters, expressed as
//!   [`ManagerRequest`] / [`ManagerResponse`]. Implemented by
//!   [`LiveStore`] itself (the in-process transport — plain method
//!   calls, byte-identical to the pre-split store) and by
//!   [`super::rpc::RemoteStore`] (the socket transport).
//!
//! On the wire every message is one **frame**: a length-prefixed,
//! FNV-1a-checksummed byte payload (the same record idioms the
//! segment log uses — `[u32 len][u64 fnv1a][payload]`, little-endian).
//! [`read_frame`] / [`write_frame`] never panic on hostile input:
//! truncated headers, oversized lengths, checksum mismatches, unknown
//! op codes, and mid-stream disconnects each surface as a typed
//! [`ProtoError`], which the daemons encode back to the peer as a
//! `Malformed` response before closing the connection.
//!
//! The PR 9 load-feedback plane crosses the boundary in response
//! *trailers*: every [`NodeResponse`] carries the node's current
//! [`ChunkBackend::io_depth`] after its body, so a remote manager's
//! adaptive placement sees the same signal an in-process one reads
//! directly.

use super::backend::{chunk_crc, BackendKind, ChunkBackend, ChunkKey, NodeRecovery};
use super::store::{CacheStats, LiveStore};
use crate::hints::TagSet;
use crate::storage::types::{FileId, NodeId, StorageError};
use std::io::{Read, Write};
use std::sync::atomic::Ordering;

/// Hard cap on a frame's payload length. Write requests carry whole
/// files, so the cap is generous; anything larger is a corrupt or
/// hostile header, not a legitimate message.
pub const FRAME_MAX: u32 = 256 << 20;

/// Frame header bytes: `u32` payload length + `u64` FNV-1a checksum.
pub const FRAME_HEADER: usize = 12;

/// Typed failure of the wire layer. Daemons map every hostile input to
/// one of these — never a panic, never a hang, never a leaked
/// connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// The stream ended (or stalled past the read deadline) inside a
    /// frame: a truncated header or a mid-stream disconnect.
    Truncated,
    /// The header's length field exceeds [`FRAME_MAX`].
    Oversized(u64),
    /// The payload did not hash to the header's FNV-1a checksum.
    BadChecksum,
    /// The payload led with an op code this peer does not speak.
    UnknownOp(u8),
    /// The op code was known but the payload body did not decode.
    BadPayload(String),
    /// The peer closed the stream cleanly between frames.
    Disconnected,
    /// An underlying socket error outside the framing itself.
    Io(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "truncated frame"),
            ProtoError::Oversized(len) => {
                write!(f, "oversized frame length {len} (cap {FRAME_MAX})")
            }
            ProtoError::BadChecksum => write!(f, "frame checksum mismatch"),
            ProtoError::UnknownOp(op) => write!(f, "unknown op code {op}"),
            ProtoError::BadPayload(why) => write!(f, "malformed payload: {why}"),
            ProtoError::Disconnected => write!(f, "peer disconnected"),
            ProtoError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Read exactly `buf.len()` bytes. `at_boundary` marks the first read
/// of a frame, where a clean EOF is a [`ProtoError::Disconnected`]
/// (the peer hung up between frames) rather than a truncation.
fn fill(r: &mut dyn Read, buf: &mut [u8], at_boundary: bool) -> Result<(), ProtoError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(if at_boundary && off == 0 {
                    ProtoError::Disconnected
                } else {
                    ProtoError::Truncated
                })
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // A half-open peer that sent part of a frame and went
                // silent: the read deadline fires and the frame is
                // truncated — the daemon must not hang forever.
                return Err(ProtoError::Truncated);
            }
            Err(e) => return Err(ProtoError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Write one frame: `[u32 len][u64 fnv1a(payload)][payload]`, one
/// buffered `write_all`.
pub fn write_frame(w: &mut dyn Write, payload: &[u8]) -> Result<(), ProtoError> {
    if payload.len() as u64 > FRAME_MAX as u64 {
        return Err(ProtoError::Oversized(payload.len() as u64));
    }
    let mut buf = Vec::with_capacity(FRAME_HEADER + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&chunk_crc(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf).map_err(|e| ProtoError::Io(e.to_string()))?;
    w.flush().map_err(|e| ProtoError::Io(e.to_string()))
}

/// Read one frame and verify its checksum. Hostile input surfaces as
/// the matching [`ProtoError`]; the payload allocation is bounded by
/// [`FRAME_MAX`] *before* any allocation happens.
pub fn read_frame(r: &mut dyn Read) -> Result<Vec<u8>, ProtoError> {
    let mut len_bytes = [0u8; 4];
    read_at_boundary(r, &mut len_bytes)?;
    read_frame_rest(r, len_bytes)
}

/// Read exactly `buf.len()` bytes at a frame boundary: a clean EOF at
/// byte zero is [`ProtoError::Disconnected`] (the peer hung up between
/// frames), anything short after that [`ProtoError::Truncated`]. A
/// daemon blocks here without a deadline — an idle pooled connection
/// is not an error.
pub fn read_at_boundary(r: &mut dyn Read, buf: &mut [u8]) -> Result<(), ProtoError> {
    fill(r, buf, true)
}

/// Finish a frame whose 4 length bytes the caller already read (the
/// two-stage server read: boundary read without a deadline, the rest
/// under one, so a half-open peer that sent a partial frame surfaces
/// as [`ProtoError::Truncated`] instead of parking the thread).
pub fn read_frame_rest(r: &mut dyn Read, len_bytes: [u8; 4]) -> Result<Vec<u8>, ProtoError> {
    let len = u32::from_le_bytes(len_bytes);
    if len > FRAME_MAX {
        return Err(ProtoError::Oversized(len as u64));
    }
    let mut crc = [0u8; 8];
    fill(r, &mut crc, false)?;
    let want_crc = u64::from_le_bytes(crc);
    let mut payload = vec![0u8; len as usize];
    fill(r, &mut payload, false)?;
    if chunk_crc(&payload) != want_crc {
        return Err(ProtoError::BadChecksum);
    }
    Ok(payload)
}

// ---------------------------------------------------------------------------
// Payload codec
// ---------------------------------------------------------------------------

/// Little-endian payload encoder (the frame layer owns the checksum).
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh encoder, leading with an op/tag byte.
    pub fn tagged(tag: u8) -> Self {
        let mut e = Enc::default();
        e.u8(tag);
        e
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` (bit pattern).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a bool as one byte.
    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    /// Append a length-prefixed byte string.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// The finished payload.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Payload decoder; every read is bounds-checked and surfaces
/// [`ProtoError::BadPayload`] instead of panicking.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode over `buf` from its first byte.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| ProtoError::BadPayload(format!("short read: want {n} more bytes")))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read an `f64` (bit pattern).
    pub fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a bool byte (0 | 1).
    pub fn bool(&mut self) -> Result<bool, ProtoError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(ProtoError::BadPayload(format!("bad bool byte {other}"))),
        }
    }

    /// Read a length-prefixed byte string.
    pub fn bytes(&mut self) -> Result<Vec<u8>, ProtoError> {
        let len = self.u64()?;
        if len > FRAME_MAX as u64 {
            return Err(ProtoError::BadPayload(format!("byte string length {len}")));
        }
        Ok(self.take(len as usize)?.to_vec())
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String, ProtoError> {
        String::from_utf8(self.bytes()?)
            .map_err(|e| ProtoError::BadPayload(format!("non-utf8 string: {e}")))
    }

    /// Require the payload fully consumed (trailing garbage is drift).
    pub fn done(&self) -> Result<(), ProtoError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(ProtoError::BadPayload(format!(
                "{} trailing bytes",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn enc_key(e: &mut Enc, key: ChunkKey) {
    e.u64(key.0 .0);
    e.u64(key.1);
}

fn dec_key(d: &mut Dec) -> Result<ChunkKey, ProtoError> {
    Ok((FileId(d.u64()?), d.u64()?))
}

fn enc_storage_err(e: &mut Enc, err: &StorageError) {
    match err {
        StorageError::NotFound(s) => {
            e.u8(0);
            e.str(s);
        }
        StorageError::AlreadyExists(s) => {
            e.u8(1);
            e.str(s);
        }
        StorageError::NoSpace(n) => {
            e.u8(2);
            e.u64(*n);
        }
        StorageError::Invalid(s) => {
            e.u8(3);
            e.str(s);
        }
    }
}

fn dec_storage_err(d: &mut Dec) -> Result<StorageError, ProtoError> {
    Ok(match d.u8()? {
        0 => StorageError::NotFound(d.str()?),
        1 => StorageError::AlreadyExists(d.str()?),
        2 => StorageError::NoSpace(d.u64()?),
        3 => StorageError::Invalid(d.str()?),
        other => return Err(ProtoError::BadPayload(format!("bad error tag {other}"))),
    })
}

fn enc_proto_err(e: &mut Enc, err: &ProtoError) {
    match err {
        ProtoError::Truncated => e.u8(0),
        ProtoError::Oversized(len) => {
            e.u8(1);
            e.u64(*len);
        }
        ProtoError::BadChecksum => e.u8(2),
        ProtoError::UnknownOp(op) => {
            e.u8(3);
            e.u8(*op);
        }
        ProtoError::BadPayload(s) => {
            e.u8(4);
            e.str(s);
        }
        ProtoError::Disconnected => e.u8(5),
        ProtoError::Io(s) => {
            e.u8(6);
            e.str(s);
        }
    }
}

fn dec_proto_err(d: &mut Dec) -> Result<ProtoError, ProtoError> {
    Ok(match d.u8()? {
        0 => ProtoError::Truncated,
        1 => ProtoError::Oversized(d.u64()?),
        2 => ProtoError::BadChecksum,
        3 => ProtoError::UnknownOp(d.u8()?),
        4 => ProtoError::BadPayload(d.str()?),
        5 => ProtoError::Disconnected,
        6 => ProtoError::Io(d.str()?),
        other => return Err(ProtoError::BadPayload(format!("bad proto-err tag {other}"))),
    })
}

fn enc_backend_kind(e: &mut Enc, kind: BackendKind) {
    e.u8(match kind {
        BackendKind::Memory => 0,
        BackendKind::Disk => 1,
        BackendKind::Seg => 2,
    });
}

fn dec_backend_kind(d: &mut Dec) -> Result<BackendKind, ProtoError> {
    Ok(match d.u8()? {
        0 => BackendKind::Memory,
        1 => BackendKind::Disk,
        2 => BackendKind::Seg,
        other => return Err(ProtoError::BadPayload(format!("bad backend tag {other}"))),
    })
}

fn enc_tags(e: &mut Enc, tags: &TagSet) {
    let pairs: Vec<(&str, &str)> = tags.iter().collect();
    e.u32(pairs.len() as u32);
    for (k, v) in pairs {
        e.str(k);
        e.str(v);
    }
}

fn dec_tags(d: &mut Dec) -> Result<TagSet, ProtoError> {
    let n = d.u32()?;
    let mut pairs = Vec::with_capacity(n.min(1024) as usize);
    for _ in 0..n {
        pairs.push((d.str()?, d.str()?));
    }
    Ok(TagSet::from_pairs(pairs))
}

// ---------------------------------------------------------------------------
// Node service
// ---------------------------------------------------------------------------

/// One storage node's remote surface — the [`ChunkBackend`] contract
/// as an exhaustive request enum, plus recovery info and shutdown.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeRequest {
    /// Liveness probe (the spawn-readiness handshake).
    Ping,
    /// Store one chunk's bytes.
    Put {
        /// Chunk key (file id + index).
        key: ChunkKey,
        /// Payload bytes.
        bytes: Vec<u8>,
    },
    /// Fetch one chunk's bytes (`None` when absent).
    Get {
        /// Chunk key.
        key: ChunkKey,
    },
    /// Remove one chunk (idempotent).
    Delete {
        /// Chunk key.
        key: ChunkKey,
    },
    /// Is the chunk present?
    Contains {
        /// Chunk key.
        key: ChunkKey,
    },
    /// Usage snapshot: used bytes, chunk count, read-error count.
    Stat,
    /// Every chunk key this node holds.
    ChunkKeys,
    /// Run background maintenance (segment compaction).
    Maintain,
    /// Static identity + what a `--reopen` salvaged at startup.
    Info,
    /// Clean daemon exit after the reply is sent.
    Shutdown,
}

const NODE_OP_PING: u8 = 1;
const NODE_OP_PUT: u8 = 2;
const NODE_OP_GET: u8 = 3;
const NODE_OP_DELETE: u8 = 4;
const NODE_OP_CONTAINS: u8 = 5;
const NODE_OP_STAT: u8 = 6;
const NODE_OP_KEYS: u8 = 7;
const NODE_OP_MAINTAIN: u8 = 8;
const NODE_OP_INFO: u8 = 9;
const NODE_OP_SHUTDOWN: u8 = 10;

impl NodeRequest {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut e;
        match self {
            NodeRequest::Ping => e = Enc::tagged(NODE_OP_PING),
            NodeRequest::Put { key, bytes } => {
                e = Enc::tagged(NODE_OP_PUT);
                enc_key(&mut e, *key);
                e.bytes(bytes);
            }
            NodeRequest::Get { key } => {
                e = Enc::tagged(NODE_OP_GET);
                enc_key(&mut e, *key);
            }
            NodeRequest::Delete { key } => {
                e = Enc::tagged(NODE_OP_DELETE);
                enc_key(&mut e, *key);
            }
            NodeRequest::Contains { key } => {
                e = Enc::tagged(NODE_OP_CONTAINS);
                enc_key(&mut e, *key);
            }
            NodeRequest::Stat => e = Enc::tagged(NODE_OP_STAT),
            NodeRequest::ChunkKeys => e = Enc::tagged(NODE_OP_KEYS),
            NodeRequest::Maintain => e = Enc::tagged(NODE_OP_MAINTAIN),
            NodeRequest::Info => e = Enc::tagged(NODE_OP_INFO),
            NodeRequest::Shutdown => e = Enc::tagged(NODE_OP_SHUTDOWN),
        }
        e.finish()
    }

    /// Parse a frame payload; unknown op codes and malformed bodies
    /// surface as typed [`ProtoError`]s.
    pub fn decode(payload: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Dec::new(payload);
        let req = match d.u8()? {
            NODE_OP_PING => NodeRequest::Ping,
            NODE_OP_PUT => NodeRequest::Put {
                key: dec_key(&mut d)?,
                bytes: d.bytes()?,
            },
            NODE_OP_GET => NodeRequest::Get {
                key: dec_key(&mut d)?,
            },
            NODE_OP_DELETE => NodeRequest::Delete {
                key: dec_key(&mut d)?,
            },
            NODE_OP_CONTAINS => NodeRequest::Contains {
                key: dec_key(&mut d)?,
            },
            NODE_OP_STAT => NodeRequest::Stat,
            NODE_OP_KEYS => NodeRequest::ChunkKeys,
            NODE_OP_MAINTAIN => NodeRequest::Maintain,
            NODE_OP_INFO => NodeRequest::Info,
            NODE_OP_SHUTDOWN => NodeRequest::Shutdown,
            other => return Err(ProtoError::UnknownOp(other)),
        };
        d.done()?;
        Ok(req)
    }
}

/// A node daemon's reply body. On the wire every reply additionally
/// carries the node's current I/O queue depth as a trailer — the load
/// plane crossing the process boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeResponse {
    /// Success with nothing to return.
    Ok,
    /// A boolean answer (`Contains`, `Maintain`).
    Bool(bool),
    /// A chunk's bytes, or `None` when the node does not hold it.
    Chunk(Option<Vec<u8>>),
    /// Usage snapshot.
    Stat {
        /// Bytes the backend holds.
        used_bytes: u64,
        /// Chunks the backend holds.
        chunk_count: u64,
        /// Reads that failed on a present chunk.
        read_errors: u64,
    },
    /// Every chunk key held.
    Keys(Vec<ChunkKey>),
    /// Static identity + reopen salvage summary.
    Info {
        /// The chunk layout this daemon runs.
        backend: BackendKind,
        /// Chunks a `--reopen` verified and kept.
        chunks_recovered: u64,
        /// Bytes across those chunks.
        bytes_recovered: u64,
    },
    /// The operation failed with a storage-layer error.
    Err(StorageError),
    /// The daemon could not make sense of the incoming frame; it
    /// reports why and closes the connection.
    Malformed(ProtoError),
}

const NODE_RE_OK: u8 = 1;
const NODE_RE_BOOL: u8 = 2;
const NODE_RE_CHUNK: u8 = 3;
const NODE_RE_STAT: u8 = 4;
const NODE_RE_KEYS: u8 = 5;
const NODE_RE_INFO: u8 = 6;
const NODE_RE_ERR: u8 = 7;
const NODE_RE_MALFORMED: u8 = 8;

impl NodeResponse {
    /// Serialize with the load trailer (`io_depth`) appended.
    pub fn encode(&self, io_depth: u64) -> Vec<u8> {
        let mut e;
        match self {
            NodeResponse::Ok => e = Enc::tagged(NODE_RE_OK),
            NodeResponse::Bool(b) => {
                e = Enc::tagged(NODE_RE_BOOL);
                e.bool(*b);
            }
            NodeResponse::Chunk(c) => {
                e = Enc::tagged(NODE_RE_CHUNK);
                match c {
                    Some(bytes) => {
                        e.bool(true);
                        e.bytes(bytes);
                    }
                    None => e.bool(false),
                }
            }
            NodeResponse::Stat {
                used_bytes,
                chunk_count,
                read_errors,
            } => {
                e = Enc::tagged(NODE_RE_STAT);
                e.u64(*used_bytes);
                e.u64(*chunk_count);
                e.u64(*read_errors);
            }
            NodeResponse::Keys(keys) => {
                e = Enc::tagged(NODE_RE_KEYS);
                e.u64(keys.len() as u64);
                for &k in keys {
                    enc_key(&mut e, k);
                }
            }
            NodeResponse::Info {
                backend,
                chunks_recovered,
                bytes_recovered,
            } => {
                e = Enc::tagged(NODE_RE_INFO);
                enc_backend_kind(&mut e, *backend);
                e.u64(*chunks_recovered);
                e.u64(*bytes_recovered);
            }
            NodeResponse::Err(err) => {
                e = Enc::tagged(NODE_RE_ERR);
                enc_storage_err(&mut e, err);
            }
            NodeResponse::Malformed(err) => {
                e = Enc::tagged(NODE_RE_MALFORMED);
                enc_proto_err(&mut e, err);
            }
        }
        e.u64(io_depth);
        e.finish()
    }

    /// Parse a reply payload, returning `(body, io_depth trailer)`.
    pub fn decode(payload: &[u8]) -> Result<(Self, u64), ProtoError> {
        let mut d = Dec::new(payload);
        let resp = match d.u8()? {
            NODE_RE_OK => NodeResponse::Ok,
            NODE_RE_BOOL => NodeResponse::Bool(d.bool()?),
            NODE_RE_CHUNK => NodeResponse::Chunk(if d.bool()? {
                Some(d.bytes()?)
            } else {
                None
            }),
            NODE_RE_STAT => NodeResponse::Stat {
                used_bytes: d.u64()?,
                chunk_count: d.u64()?,
                read_errors: d.u64()?,
            },
            NODE_RE_KEYS => {
                let n = d.u64()?;
                let mut keys = Vec::with_capacity(n.min(1 << 20) as usize);
                for _ in 0..n {
                    keys.push(dec_key(&mut d)?);
                }
                NodeResponse::Keys(keys)
            }
            NODE_RE_INFO => NodeResponse::Info {
                backend: dec_backend_kind(&mut d)?,
                chunks_recovered: d.u64()?,
                bytes_recovered: d.u64()?,
            },
            NODE_RE_ERR => NodeResponse::Err(dec_storage_err(&mut d)?),
            NODE_RE_MALFORMED => NodeResponse::Malformed(dec_proto_err(&mut d)?),
            other => return Err(ProtoError::UnknownOp(other)),
        };
        let io_depth = d.u64()?;
        d.done()?;
        Ok((resp, io_depth))
    }
}

/// The transport-agnostic node service: one request in, one reply out.
/// [`NodeHost`] implements it over a real backend; the wire server in
/// `live::rpc` serves any implementation.
pub trait NodeService: Send + Sync {
    /// Handle one request.
    fn handle(&self, req: NodeRequest) -> NodeResponse;
    /// Current I/O queue depth — the reply trailer's load signal.
    fn io_depth(&self) -> u64;
}

/// A node daemon's state: the chunk backend it serves plus what a
/// `--reopen` salvaged at startup.
pub struct NodeHost {
    backend: Box<dyn ChunkBackend>,
    kind: BackendKind,
    recovery: Option<NodeRecovery>,
}

impl NodeHost {
    /// Wrap `backend` (of layout `kind`) with optional reopen salvage
    /// info.
    pub fn new(
        backend: Box<dyn ChunkBackend>,
        kind: BackendKind,
        recovery: Option<NodeRecovery>,
    ) -> Self {
        NodeHost {
            backend,
            kind,
            recovery,
        }
    }

    /// The wrapped backend (tests; the service surface is `handle`).
    pub fn backend(&self) -> &dyn ChunkBackend {
        self.backend.as_ref()
    }
}

impl NodeService for NodeHost {
    fn handle(&self, req: NodeRequest) -> NodeResponse {
        match req {
            NodeRequest::Ping | NodeRequest::Shutdown => NodeResponse::Ok,
            NodeRequest::Put { key, bytes } => match self.backend.put(key, &bytes) {
                Ok(()) => NodeResponse::Ok,
                Err(e) => NodeResponse::Err(e),
            },
            NodeRequest::Get { key } => match self.backend.get(key) {
                Ok(c) => NodeResponse::Chunk(c),
                Err(e) => NodeResponse::Err(e),
            },
            NodeRequest::Delete { key } => {
                self.backend.delete(key);
                NodeResponse::Ok
            }
            NodeRequest::Contains { key } => NodeResponse::Bool(self.backend.contains(key)),
            NodeRequest::Stat => NodeResponse::Stat {
                used_bytes: self.backend.used_bytes(),
                chunk_count: self.backend.chunk_count() as u64,
                read_errors: self.backend.read_errors(),
            },
            NodeRequest::ChunkKeys => NodeResponse::Keys(self.backend.chunk_keys()),
            NodeRequest::Maintain => NodeResponse::Bool(self.backend.maintain()),
            NodeRequest::Info => NodeResponse::Info {
                backend: self.kind,
                chunks_recovered: self
                    .recovery
                    .as_ref()
                    .map(|r| r.chunks_recovered as u64)
                    .unwrap_or(0),
                bytes_recovered: self.recovery.as_ref().map(|r| r.bytes_recovered).unwrap_or(0),
            },
        }
    }

    fn io_depth(&self) -> u64 {
        self.backend.io_depth()
    }
}

// ---------------------------------------------------------------------------
// Manager service
// ---------------------------------------------------------------------------

/// Static facts about a manager deployment, fetched once per client
/// connection (`Hello`) and cached — they never change over a store's
/// lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ManagerInfo {
    /// Storage nodes behind the manager.
    pub n_nodes: usize,
    /// Chunk layout the node tier runs.
    pub backend: BackendKind,
    /// Does the registry expose the `location` attribute (WOSS) or
    /// not (DSS baseline)?
    pub exposes_location: bool,
    /// Load-aware placement/read decisions on?
    pub adaptive: bool,
    /// Hot-chunk cache tier configured?
    pub cache_enabled: bool,
    /// Scratch-lifetime reclamation enforced?
    pub lifetime_enabled: bool,
}

/// Lock-free store counters, snapshotted in one round-trip.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Bytes written through `write_file`.
    pub bytes_written: u64,
    /// Bytes returned by `read_file`.
    pub bytes_read: u64,
    /// Chunk reads served from the reader's own node.
    pub local_reads: u64,
    /// Chunk reads that fetched from another node.
    pub remote_reads: u64,
    /// `set-attribute` operations (top-down channel).
    pub setattr_ops: u64,
    /// `get-attribute` operations (bottom-up channel).
    pub getattr_ops: u64,
    /// Replica copies completed by the background pool.
    pub background_copies: u64,
    /// Chunks still below replica count (churn restores draining).
    pub under_replicated: u64,
    /// Bytes landed on replacement holders by churn re-replication.
    pub bytes_rereplicated: u64,
    /// Chunks landed on replacement holders.
    pub chunks_rereplicated: u64,
    /// Files that survived a reopen into this store.
    pub recovered_files: u64,
    /// Replication/I/O flush barriers that hit their deadline
    /// ([`super::store::LiveTuning::flush_timeout_ms`]).
    pub flush_timeouts: u64,
}

/// The manager/metadata surface, transport-agnostic: everything the
/// engine, scenario harness, and CLI need from a live store.
/// [`LiveStore`] implements it with plain method calls (the in-process
/// transport — the default, byte-identical to the pre-split store);
/// [`super::rpc::RemoteStore`] implements it over the wire.
pub trait ManagerService: Send + Sync {
    /// Static deployment facts.
    fn hello(&self) -> ManagerInfo;
    /// Write a file on behalf of `client` with `tags`.
    fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError>;
    /// Read a file back on behalf of `client`.
    fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError>;
    /// Delete a file and reclaim its chunks.
    fn delete_file(&self, path: &str) -> Result<(), StorageError>;
    /// Set an extended attribute (top-down channel).
    fn set_attr(&self, path: &str, key: &str, value: &str);
    /// Get an extended attribute (bottom-up channel).
    fn get_attr(&self, path: &str, key: &str) -> Option<String>;
    /// Logical size of a file, `None` when absent.
    fn file_size(&self, path: &str) -> Option<u64>;
    /// Replica holders of a file's first chunk.
    fn locations(&self, path: &str) -> Vec<NodeId>;
    /// Promote a file's chunks into `client`'s cache tier.
    fn prefetch(&self, client: NodeId, path: &str) -> Result<usize, StorageError>;
    /// The adaptive read-cost score for one node.
    fn node_read_cost(&self, node: NodeId) -> f64;
    /// Barrier: drain background replication + the I/O pool.
    fn flush(&self);
    /// Cache-tier counters + latency percentiles.
    fn cache_stats(&self) -> CacheStats;
    /// Lock-free counter snapshot.
    fn counters(&self) -> StoreCounters;
    /// Kill a node and queue re-replication; returns jobs queued.
    fn fail_node(&self, node: NodeId) -> usize;
    /// Bring a failed node back; returns stale chunks swept.
    fn join_node(&self, node: NodeId) -> usize;
    /// Is the node serving?
    fn is_alive(&self, node: NodeId) -> bool;
    /// Bytes held per node backend.
    fn backend_used_bytes(&self) -> Vec<u64>;
    /// Clean shutdown (snapshot + CLEAN marker on persistent tiers).
    fn shutdown_store(&self);
}

impl ManagerService for LiveStore {
    fn hello(&self) -> ManagerInfo {
        ManagerInfo {
            n_nodes: self.n_nodes(),
            backend: self.backend_kind(),
            exposes_location: self.exposes_location(),
            adaptive: self.adaptive(),
            cache_enabled: self.cache_enabled(),
            lifetime_enabled: self.lifetime_enabled(),
        }
    }

    fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError> {
        LiveStore::write_file(self, client, path, data, tags)
    }

    fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError> {
        LiveStore::read_file(self, client, path)
    }

    fn delete_file(&self, path: &str) -> Result<(), StorageError> {
        self.delete(path)
    }

    fn set_attr(&self, path: &str, key: &str, value: &str) {
        self.set_xattr(path, key, value);
    }

    fn get_attr(&self, path: &str, key: &str) -> Option<String> {
        self.get_xattr(path, key)
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        LiveStore::file_size(self, path)
    }

    fn locations(&self, path: &str) -> Vec<NodeId> {
        LiveStore::locations(self, path)
    }

    fn prefetch(&self, client: NodeId, path: &str) -> Result<usize, StorageError> {
        LiveStore::prefetch(self, client, path)
    }

    fn node_read_cost(&self, node: NodeId) -> f64 {
        LiveStore::node_read_cost(self, node)
    }

    fn flush(&self) {
        self.flush_replication();
    }

    fn cache_stats(&self) -> CacheStats {
        LiveStore::cache_stats(self)
    }

    fn counters(&self) -> StoreCounters {
        StoreCounters {
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            local_reads: self.local_reads.load(Ordering::Relaxed),
            remote_reads: self.remote_reads.load(Ordering::Relaxed),
            setattr_ops: self.setattr_ops.load(Ordering::Relaxed),
            getattr_ops: self.getattr_ops.load(Ordering::Relaxed),
            background_copies: self.background_copies(),
            under_replicated: self.under_replicated(),
            bytes_rereplicated: self.bytes_rereplicated(),
            chunks_rereplicated: self.chunks_rereplicated(),
            recovered_files: self
                .recovery_report()
                .map(|r| r.files_recovered as u64)
                .unwrap_or(0),
            flush_timeouts: self.flush_timeouts(),
        }
    }

    fn fail_node(&self, node: NodeId) -> usize {
        LiveStore::fail_node(self, node)
    }

    fn join_node(&self, node: NodeId) -> usize {
        LiveStore::join_node(self, node)
    }

    fn is_alive(&self, node: NodeId) -> bool {
        LiveStore::is_alive(self, node)
    }

    fn backend_used_bytes(&self) -> Vec<u64> {
        LiveStore::backend_used_bytes(self)
    }

    fn shutdown_store(&self) {
        self.shutdown();
    }
}

/// The manager wire surface — every [`ManagerService`] method as a
/// typed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ManagerRequest {
    /// Static deployment facts (the connect handshake).
    Hello,
    /// `write_file`.
    WriteFile {
        /// Requesting client node.
        client: u64,
        /// Namespace path.
        path: String,
        /// Hint tags.
        tags: TagSet,
        /// File bytes.
        data: Vec<u8>,
    },
    /// `read_file`.
    ReadFile {
        /// Requesting client node.
        client: u64,
        /// Namespace path.
        path: String,
    },
    /// `delete_file`.
    Delete {
        /// Namespace path.
        path: String,
    },
    /// `set_attr`.
    SetAttr {
        /// Namespace path.
        path: String,
        /// Attribute key.
        key: String,
        /// Attribute value.
        value: String,
    },
    /// `get_attr`.
    GetAttr {
        /// Namespace path.
        path: String,
        /// Attribute key.
        key: String,
    },
    /// `file_size`.
    FileSize {
        /// Namespace path.
        path: String,
    },
    /// `locations`.
    Locations {
        /// Namespace path.
        path: String,
    },
    /// `prefetch`.
    Prefetch {
        /// Requesting client node.
        client: u64,
        /// Namespace path.
        path: String,
    },
    /// `node_read_cost`.
    NodeReadCost {
        /// Node index.
        node: u64,
    },
    /// `flush` (replication + I/O barrier).
    Flush,
    /// `cache_stats`.
    CacheStats,
    /// `counters`.
    Counters,
    /// `fail_node`.
    FailNode {
        /// Node index.
        node: u64,
    },
    /// `join_node`.
    JoinNode {
        /// Node index.
        node: u64,
    },
    /// `is_alive`.
    IsAlive {
        /// Node index.
        node: u64,
    },
    /// `backend_used_bytes`.
    BackendUsedBytes,
    /// Clean store shutdown, then daemon exit after the reply.
    Shutdown,
}

const MGR_OP_HELLO: u8 = 1;
const MGR_OP_WRITE: u8 = 2;
const MGR_OP_READ: u8 = 3;
const MGR_OP_DELETE: u8 = 4;
const MGR_OP_SETATTR: u8 = 5;
const MGR_OP_GETATTR: u8 = 6;
const MGR_OP_SIZE: u8 = 7;
const MGR_OP_LOCATIONS: u8 = 8;
const MGR_OP_PREFETCH: u8 = 9;
const MGR_OP_READCOST: u8 = 10;
const MGR_OP_FLUSH: u8 = 11;
const MGR_OP_CACHESTATS: u8 = 12;
const MGR_OP_COUNTERS: u8 = 13;
const MGR_OP_FAIL: u8 = 14;
const MGR_OP_JOIN: u8 = 15;
const MGR_OP_ALIVE: u8 = 16;
const MGR_OP_USED: u8 = 17;
const MGR_OP_SHUTDOWN: u8 = 18;

impl ManagerRequest {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut e;
        match self {
            ManagerRequest::Hello => e = Enc::tagged(MGR_OP_HELLO),
            ManagerRequest::WriteFile {
                client,
                path,
                tags,
                data,
            } => {
                e = Enc::tagged(MGR_OP_WRITE);
                e.u64(*client);
                e.str(path);
                enc_tags(&mut e, tags);
                e.bytes(data);
            }
            ManagerRequest::ReadFile { client, path } => {
                e = Enc::tagged(MGR_OP_READ);
                e.u64(*client);
                e.str(path);
            }
            ManagerRequest::Delete { path } => {
                e = Enc::tagged(MGR_OP_DELETE);
                e.str(path);
            }
            ManagerRequest::SetAttr { path, key, value } => {
                e = Enc::tagged(MGR_OP_SETATTR);
                e.str(path);
                e.str(key);
                e.str(value);
            }
            ManagerRequest::GetAttr { path, key } => {
                e = Enc::tagged(MGR_OP_GETATTR);
                e.str(path);
                e.str(key);
            }
            ManagerRequest::FileSize { path } => {
                e = Enc::tagged(MGR_OP_SIZE);
                e.str(path);
            }
            ManagerRequest::Locations { path } => {
                e = Enc::tagged(MGR_OP_LOCATIONS);
                e.str(path);
            }
            ManagerRequest::Prefetch { client, path } => {
                e = Enc::tagged(MGR_OP_PREFETCH);
                e.u64(*client);
                e.str(path);
            }
            ManagerRequest::NodeReadCost { node } => {
                e = Enc::tagged(MGR_OP_READCOST);
                e.u64(*node);
            }
            ManagerRequest::Flush => e = Enc::tagged(MGR_OP_FLUSH),
            ManagerRequest::CacheStats => e = Enc::tagged(MGR_OP_CACHESTATS),
            ManagerRequest::Counters => e = Enc::tagged(MGR_OP_COUNTERS),
            ManagerRequest::FailNode { node } => {
                e = Enc::tagged(MGR_OP_FAIL);
                e.u64(*node);
            }
            ManagerRequest::JoinNode { node } => {
                e = Enc::tagged(MGR_OP_JOIN);
                e.u64(*node);
            }
            ManagerRequest::IsAlive { node } => {
                e = Enc::tagged(MGR_OP_ALIVE);
                e.u64(*node);
            }
            ManagerRequest::BackendUsedBytes => e = Enc::tagged(MGR_OP_USED),
            ManagerRequest::Shutdown => e = Enc::tagged(MGR_OP_SHUTDOWN),
        }
        e.finish()
    }

    /// Parse a frame payload.
    pub fn decode(payload: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Dec::new(payload);
        let req = match d.u8()? {
            MGR_OP_HELLO => ManagerRequest::Hello,
            MGR_OP_WRITE => ManagerRequest::WriteFile {
                client: d.u64()?,
                path: d.str()?,
                tags: dec_tags(&mut d)?,
                data: d.bytes()?,
            },
            MGR_OP_READ => ManagerRequest::ReadFile {
                client: d.u64()?,
                path: d.str()?,
            },
            MGR_OP_DELETE => ManagerRequest::Delete { path: d.str()? },
            MGR_OP_SETATTR => ManagerRequest::SetAttr {
                path: d.str()?,
                key: d.str()?,
                value: d.str()?,
            },
            MGR_OP_GETATTR => ManagerRequest::GetAttr {
                path: d.str()?,
                key: d.str()?,
            },
            MGR_OP_SIZE => ManagerRequest::FileSize { path: d.str()? },
            MGR_OP_LOCATIONS => ManagerRequest::Locations { path: d.str()? },
            MGR_OP_PREFETCH => ManagerRequest::Prefetch {
                client: d.u64()?,
                path: d.str()?,
            },
            MGR_OP_READCOST => ManagerRequest::NodeReadCost { node: d.u64()? },
            MGR_OP_FLUSH => ManagerRequest::Flush,
            MGR_OP_CACHESTATS => ManagerRequest::CacheStats,
            MGR_OP_COUNTERS => ManagerRequest::Counters,
            MGR_OP_FAIL => ManagerRequest::FailNode { node: d.u64()? },
            MGR_OP_JOIN => ManagerRequest::JoinNode { node: d.u64()? },
            MGR_OP_ALIVE => ManagerRequest::IsAlive { node: d.u64()? },
            MGR_OP_USED => ManagerRequest::BackendUsedBytes,
            MGR_OP_SHUTDOWN => ManagerRequest::Shutdown,
            other => return Err(ProtoError::UnknownOp(other)),
        };
        d.done()?;
        Ok(req)
    }
}

/// A manager daemon's reply.
#[derive(Debug, Clone, PartialEq)]
pub enum ManagerResponse {
    /// Success with nothing to return.
    Ok,
    /// Deployment facts (`Hello`).
    Info(ManagerInfo),
    /// File bytes (`ReadFile`).
    Bytes(Vec<u8>),
    /// An optional size (`FileSize`).
    Size(Option<u64>),
    /// An optional attribute value (`GetAttr`).
    Attr(Option<String>),
    /// Node indices (`Locations`).
    Nodes(Vec<u64>),
    /// A float answer (`NodeReadCost`).
    F64(f64),
    /// A boolean answer (`IsAlive`).
    Bool(bool),
    /// A count (`Prefetch` chunks, `FailNode` jobs, `JoinNode` sweeps).
    Count(u64),
    /// Cache-tier stats (`CacheStats`).
    Stats(CacheStats),
    /// Counter snapshot (`Counters`).
    Counters(StoreCounters),
    /// Per-node byte totals (`BackendUsedBytes`).
    U64s(Vec<u64>),
    /// The operation failed with a storage-layer error.
    Err(StorageError),
    /// The daemon could not make sense of the incoming frame.
    Malformed(ProtoError),
}

const MGR_RE_OK: u8 = 1;
const MGR_RE_INFO: u8 = 2;
const MGR_RE_BYTES: u8 = 3;
const MGR_RE_SIZE: u8 = 4;
const MGR_RE_ATTR: u8 = 5;
const MGR_RE_NODES: u8 = 6;
const MGR_RE_F64: u8 = 7;
const MGR_RE_BOOL: u8 = 8;
const MGR_RE_COUNT: u8 = 9;
const MGR_RE_STATS: u8 = 10;
const MGR_RE_COUNTERS: u8 = 11;
const MGR_RE_U64S: u8 = 12;
const MGR_RE_ERR: u8 = 13;
const MGR_RE_MALFORMED: u8 = 14;

impl ManagerResponse {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut e;
        match self {
            ManagerResponse::Ok => e = Enc::tagged(MGR_RE_OK),
            ManagerResponse::Info(info) => {
                e = Enc::tagged(MGR_RE_INFO);
                e.u64(info.n_nodes as u64);
                enc_backend_kind(&mut e, info.backend);
                e.bool(info.exposes_location);
                e.bool(info.adaptive);
                e.bool(info.cache_enabled);
                e.bool(info.lifetime_enabled);
            }
            ManagerResponse::Bytes(b) => {
                e = Enc::tagged(MGR_RE_BYTES);
                e.bytes(b);
            }
            ManagerResponse::Size(s) => {
                e = Enc::tagged(MGR_RE_SIZE);
                match s {
                    Some(v) => {
                        e.bool(true);
                        e.u64(*v);
                    }
                    None => e.bool(false),
                }
            }
            ManagerResponse::Attr(a) => {
                e = Enc::tagged(MGR_RE_ATTR);
                match a {
                    Some(v) => {
                        e.bool(true);
                        e.str(v);
                    }
                    None => e.bool(false),
                }
            }
            ManagerResponse::Nodes(ns) => {
                e = Enc::tagged(MGR_RE_NODES);
                e.u64(ns.len() as u64);
                for &n in ns {
                    e.u64(n);
                }
            }
            ManagerResponse::F64(v) => {
                e = Enc::tagged(MGR_RE_F64);
                e.f64(*v);
            }
            ManagerResponse::Bool(b) => {
                e = Enc::tagged(MGR_RE_BOOL);
                e.bool(*b);
            }
            ManagerResponse::Count(c) => {
                e = Enc::tagged(MGR_RE_COUNT);
                e.u64(*c);
            }
            ManagerResponse::Stats(s) => {
                e = Enc::tagged(MGR_RE_STATS);
                e.u64(s.resident.len() as u64);
                for &r in &s.resident {
                    e.u64(r);
                }
                e.u64(s.peak_node_resident);
                e.u64(s.hits);
                e.u64(s.insertions);
                e.u64(s.evictions);
                e.u64(s.prefetched);
                e.u64(s.spilled);
                e.u64(s.pinned_entries);
                e.u64(s.files_reclaimed);
                e.u64(s.bytes_reclaimed);
                e.u64(s.read_errors);
                for v in [
                    s.put_p50_us,
                    s.put_p95_us,
                    s.put_p99_us,
                    s.get_p50_us,
                    s.get_p95_us,
                    s.get_p99_us,
                    s.spill_p50_us,
                    s.spill_p95_us,
                    s.spill_p99_us,
                ] {
                    e.f64(v);
                }
            }
            ManagerResponse::Counters(c) => {
                e = Enc::tagged(MGR_RE_COUNTERS);
                for v in [
                    c.bytes_written,
                    c.bytes_read,
                    c.local_reads,
                    c.remote_reads,
                    c.setattr_ops,
                    c.getattr_ops,
                    c.background_copies,
                    c.under_replicated,
                    c.bytes_rereplicated,
                    c.chunks_rereplicated,
                    c.recovered_files,
                    c.flush_timeouts,
                ] {
                    e.u64(v);
                }
            }
            ManagerResponse::U64s(vs) => {
                e = Enc::tagged(MGR_RE_U64S);
                e.u64(vs.len() as u64);
                for &v in vs {
                    e.u64(v);
                }
            }
            ManagerResponse::Err(err) => {
                e = Enc::tagged(MGR_RE_ERR);
                enc_storage_err(&mut e, err);
            }
            ManagerResponse::Malformed(err) => {
                e = Enc::tagged(MGR_RE_MALFORMED);
                enc_proto_err(&mut e, err);
            }
        }
        e.finish()
    }

    /// Parse a frame payload.
    pub fn decode(payload: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Dec::new(payload);
        let resp = match d.u8()? {
            MGR_RE_OK => ManagerResponse::Ok,
            MGR_RE_INFO => ManagerResponse::Info(ManagerInfo {
                n_nodes: d.u64()? as usize,
                backend: dec_backend_kind(&mut d)?,
                exposes_location: d.bool()?,
                adaptive: d.bool()?,
                cache_enabled: d.bool()?,
                lifetime_enabled: d.bool()?,
            }),
            MGR_RE_BYTES => ManagerResponse::Bytes(d.bytes()?),
            MGR_RE_SIZE => ManagerResponse::Size(if d.bool()? { Some(d.u64()?) } else { None }),
            MGR_RE_ATTR => ManagerResponse::Attr(if d.bool()? { Some(d.str()?) } else { None }),
            MGR_RE_NODES => {
                let n = d.u64()?;
                let mut ns = Vec::with_capacity(n.min(1 << 16) as usize);
                for _ in 0..n {
                    ns.push(d.u64()?);
                }
                ManagerResponse::Nodes(ns)
            }
            MGR_RE_F64 => ManagerResponse::F64(d.f64()?),
            MGR_RE_BOOL => ManagerResponse::Bool(d.bool()?),
            MGR_RE_COUNT => ManagerResponse::Count(d.u64()?),
            MGR_RE_STATS => {
                let n = d.u64()?;
                let mut resident = Vec::with_capacity(n.min(1 << 16) as usize);
                for _ in 0..n {
                    resident.push(d.u64()?);
                }
                ManagerResponse::Stats(CacheStats {
                    resident,
                    peak_node_resident: d.u64()?,
                    hits: d.u64()?,
                    insertions: d.u64()?,
                    evictions: d.u64()?,
                    prefetched: d.u64()?,
                    spilled: d.u64()?,
                    pinned_entries: d.u64()?,
                    files_reclaimed: d.u64()?,
                    bytes_reclaimed: d.u64()?,
                    read_errors: d.u64()?,
                    put_p50_us: d.f64()?,
                    put_p95_us: d.f64()?,
                    put_p99_us: d.f64()?,
                    get_p50_us: d.f64()?,
                    get_p95_us: d.f64()?,
                    get_p99_us: d.f64()?,
                    spill_p50_us: d.f64()?,
                    spill_p95_us: d.f64()?,
                    spill_p99_us: d.f64()?,
                })
            }
            MGR_RE_COUNTERS => ManagerResponse::Counters(StoreCounters {
                bytes_written: d.u64()?,
                bytes_read: d.u64()?,
                local_reads: d.u64()?,
                remote_reads: d.u64()?,
                setattr_ops: d.u64()?,
                getattr_ops: d.u64()?,
                background_copies: d.u64()?,
                under_replicated: d.u64()?,
                bytes_rereplicated: d.u64()?,
                chunks_rereplicated: d.u64()?,
                recovered_files: d.u64()?,
                flush_timeouts: d.u64()?,
            }),
            MGR_RE_U64S => {
                let n = d.u64()?;
                let mut vs = Vec::with_capacity(n.min(1 << 16) as usize);
                for _ in 0..n {
                    vs.push(d.u64()?);
                }
                ManagerResponse::U64s(vs)
            }
            MGR_RE_ERR => ManagerResponse::Err(dec_storage_err(&mut d)?),
            MGR_RE_MALFORMED => ManagerResponse::Malformed(dec_proto_err(&mut d)?),
            other => return Err(ProtoError::UnknownOp(other)),
        };
        d.done()?;
        Ok(resp)
    }
}

/// Route one typed request to a [`ManagerService`] implementation.
/// This is the whole in-process transport: `decode → dispatch →
/// encode` must behave identically to calling the service directly,
/// which `proto` tests pin.
pub fn dispatch_manager(svc: &dyn ManagerService, req: ManagerRequest) -> ManagerResponse {
    match req {
        ManagerRequest::Hello => ManagerResponse::Info(svc.hello()),
        ManagerRequest::WriteFile {
            client,
            path,
            tags,
            data,
        } => match svc.write_file(NodeId(client as usize), &path, &data, &tags) {
            Ok(()) => ManagerResponse::Ok,
            Err(e) => ManagerResponse::Err(e),
        },
        ManagerRequest::ReadFile { client, path } => {
            match svc.read_file(NodeId(client as usize), &path) {
                Ok(bytes) => ManagerResponse::Bytes(bytes),
                Err(e) => ManagerResponse::Err(e),
            }
        }
        ManagerRequest::Delete { path } => match svc.delete_file(&path) {
            Ok(()) => ManagerResponse::Ok,
            Err(e) => ManagerResponse::Err(e),
        },
        ManagerRequest::SetAttr { path, key, value } => {
            svc.set_attr(&path, &key, &value);
            ManagerResponse::Ok
        }
        ManagerRequest::GetAttr { path, key } => ManagerResponse::Attr(svc.get_attr(&path, &key)),
        ManagerRequest::FileSize { path } => ManagerResponse::Size(svc.file_size(&path)),
        ManagerRequest::Locations { path } => ManagerResponse::Nodes(
            svc.locations(&path).into_iter().map(|n| n.0 as u64).collect(),
        ),
        ManagerRequest::Prefetch { client, path } => {
            match svc.prefetch(NodeId(client as usize), &path) {
                Ok(n) => ManagerResponse::Count(n as u64),
                Err(e) => ManagerResponse::Err(e),
            }
        }
        ManagerRequest::NodeReadCost { node } => {
            ManagerResponse::F64(svc.node_read_cost(NodeId(node as usize)))
        }
        ManagerRequest::Flush => {
            svc.flush();
            ManagerResponse::Ok
        }
        ManagerRequest::CacheStats => ManagerResponse::Stats(svc.cache_stats()),
        ManagerRequest::Counters => ManagerResponse::Counters(svc.counters()),
        ManagerRequest::FailNode { node } => {
            ManagerResponse::Count(svc.fail_node(NodeId(node as usize)) as u64)
        }
        ManagerRequest::JoinNode { node } => {
            ManagerResponse::Count(svc.join_node(NodeId(node as usize)) as u64)
        }
        ManagerRequest::IsAlive { node } => {
            ManagerResponse::Bool(svc.is_alive(NodeId(node as usize)))
        }
        ManagerRequest::BackendUsedBytes => ManagerResponse::U64s(svc.backend_used_bytes()),
        ManagerRequest::Shutdown => {
            svc.shutdown_store();
            ManagerResponse::Ok
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Registry;

    fn round_trip_node(req: NodeRequest) {
        let decoded = NodeRequest::decode(&req.encode()).unwrap();
        assert_eq!(req, decoded);
    }

    fn round_trip_mgr(req: ManagerRequest) {
        let decoded = ManagerRequest::decode(&req.encode()).unwrap();
        assert_eq!(req, decoded);
    }

    #[test]
    fn frames_round_trip_and_reject_corruption() {
        let payload = b"the quick brown fox".to_vec();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(read_frame(&mut buf.as_slice()).unwrap(), payload);

        // Bit-flip in the payload → checksum mismatch.
        let mut corrupt = buf.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0x40;
        assert_eq!(
            read_frame(&mut corrupt.as_slice()),
            Err(ProtoError::BadChecksum)
        );

        // Truncated mid-payload.
        let cut = &buf[..buf.len() - 3];
        assert_eq!(read_frame(&mut &cut[..]), Err(ProtoError::Truncated));

        // Truncated mid-header.
        assert_eq!(read_frame(&mut &buf[..2]), Err(ProtoError::Truncated));

        // Clean EOF before any byte → disconnect, not truncation.
        assert_eq!(read_frame(&mut &buf[..0]), Err(ProtoError::Disconnected));

        // Oversized length field, rejected before allocation.
        let mut huge = (FRAME_MAX + 1).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 8]);
        assert_eq!(
            read_frame(&mut huge.as_slice()),
            Err(ProtoError::Oversized((FRAME_MAX + 1) as u64))
        );
    }

    #[test]
    fn every_message_round_trips() {
        round_trip_node(NodeRequest::Ping);
        round_trip_node(NodeRequest::Put {
            key: (FileId(7), 3),
            bytes: vec![1, 2, 3],
        });
        round_trip_node(NodeRequest::Get { key: (FileId(1), 0) });
        round_trip_node(NodeRequest::Delete { key: (FileId(2), 9) });
        round_trip_node(NodeRequest::Contains { key: (FileId(3), 1) });
        round_trip_node(NodeRequest::Stat);
        round_trip_node(NodeRequest::ChunkKeys);
        round_trip_node(NodeRequest::Maintain);
        round_trip_node(NodeRequest::Info);
        round_trip_node(NodeRequest::Shutdown);

        for resp in [
            NodeResponse::Ok,
            NodeResponse::Bool(true),
            NodeResponse::Chunk(Some(vec![9, 9])),
            NodeResponse::Chunk(None),
            NodeResponse::Stat {
                used_bytes: 10,
                chunk_count: 2,
                read_errors: 1,
            },
            NodeResponse::Keys(vec![(FileId(1), 0), (FileId(2), 5)]),
            NodeResponse::Info {
                backend: BackendKind::Seg,
                chunks_recovered: 4,
                bytes_recovered: 4096,
            },
            NodeResponse::Err(StorageError::NoSpace(123)),
            NodeResponse::Malformed(ProtoError::UnknownOp(200)),
        ] {
            let (decoded, depth) = NodeResponse::decode(&resp.encode(42)).unwrap();
            assert_eq!(decoded, resp);
            assert_eq!(depth, 42, "io_depth trailer survives the trip");
        }

        round_trip_mgr(ManagerRequest::Hello);
        round_trip_mgr(ManagerRequest::WriteFile {
            client: 1,
            path: "/a/b".into(),
            tags: TagSet::from_pairs([("Replication", "2")]),
            data: vec![0xAB; 100],
        });
        round_trip_mgr(ManagerRequest::ReadFile {
            client: 0,
            path: "/a/b".into(),
        });
        round_trip_mgr(ManagerRequest::GetAttr {
            path: "/a".into(),
            key: "location".into(),
        });
        round_trip_mgr(ManagerRequest::Counters);
        round_trip_mgr(ManagerRequest::Shutdown);

        let stats = CacheStats {
            resident: vec![1, 2, 3],
            hits: 7,
            put_p99_us: 1.5,
            ..CacheStats::default()
        };
        match ManagerResponse::decode(&ManagerResponse::Stats(stats.clone()).encode()).unwrap() {
            ManagerResponse::Stats(s) => {
                assert_eq!(s.resident, stats.resident);
                assert_eq!(s.hits, 7);
                assert_eq!(s.put_p99_us, 1.5);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn unknown_ops_and_bad_payloads_are_typed_errors() {
        assert_eq!(NodeRequest::decode(&[250]), Err(ProtoError::UnknownOp(250)));
        assert_eq!(
            ManagerRequest::decode(&[99]),
            Err(ProtoError::UnknownOp(99))
        );
        // A put op with a short body.
        assert!(matches!(
            NodeRequest::decode(&[NODE_OP_PUT, 1, 2]),
            Err(ProtoError::BadPayload(_))
        ));
        // Trailing garbage after a complete message is drift, not noise.
        let mut payload = NodeRequest::Ping.encode();
        payload.push(0);
        assert!(matches!(
            NodeRequest::decode(&payload),
            Err(ProtoError::BadPayload(_))
        ));
        assert!(NodeRequest::decode(&[]).is_err());
    }

    #[test]
    fn typed_dispatch_matches_direct_store_calls() {
        // The in-process transport equivalence: the same operations
        // through `encode → decode → dispatch_manager` and through
        // direct method calls must leave two stores with identical
        // observable state.
        let direct = LiveStore::new(Registry::woss(), 3, u64::MAX / 2);
        let routed = LiveStore::new(Registry::woss(), 3, u64::MAX / 2);
        let via_wire = |req: ManagerRequest| {
            let payload = req.encode();
            let req = ManagerRequest::decode(&payload).unwrap();
            let resp = dispatch_manager(&routed, req);
            ManagerResponse::decode(&resp.encode()).unwrap()
        };

        let tags = TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]);
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        for f in 0..8 {
            let path = format!("/eq/f{f}");
            direct
                .write_file(NodeId(f % 3), &path, &data, &tags)
                .unwrap();
            match via_wire(ManagerRequest::WriteFile {
                client: (f % 3) as u64,
                path: path.clone(),
                tags: tags.clone(),
                data: data.clone(),
            }) {
                ManagerResponse::Ok => {}
                other => panic!("routed write failed: {other:?}"),
            }
        }
        direct.flush_replication();
        assert!(matches!(via_wire(ManagerRequest::Flush), ManagerResponse::Ok));

        for f in 0..8 {
            let path = format!("/eq/f{f}");
            let a = direct.read_file(NodeId(0), &path).unwrap();
            let b = match via_wire(ManagerRequest::ReadFile {
                client: 0,
                path: path.clone(),
            }) {
                ManagerResponse::Bytes(b) => b,
                other => panic!("routed read failed: {other:?}"),
            };
            assert_eq!(a, b, "bytes identical through the typed boundary");
            let la: Vec<u64> = direct.locations(&path).iter().map(|n| n.0 as u64).collect();
            let lb = match via_wire(ManagerRequest::Locations { path }) {
                ManagerResponse::Nodes(ns) => ns,
                other => panic!("routed locations failed: {other:?}"),
            };
            assert_eq!(la, lb, "placement identical through the typed boundary");
        }
        assert_eq!(
            direct.backend_used_bytes(),
            match via_wire(ManagerRequest::BackendUsedBytes) {
                ManagerResponse::U64s(v) => v,
                other => panic!("{other:?}"),
            }
        );
        let ca = ManagerService::counters(&direct);
        let cb = match via_wire(ManagerRequest::Counters) {
            ManagerResponse::Counters(c) => c,
            other => panic!("{other:?}"),
        };
        assert_eq!(ca.bytes_written, cb.bytes_written);
        assert_eq!(ca.local_reads + ca.remote_reads, cb.local_reads + cb.remote_reads);
    }
}
