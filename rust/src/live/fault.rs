//! Deterministic fault injection for the live store's chunk backends.
//!
//! The crash-consistency and failover machinery built in PRs 2–5 was
//! exercised only by cooperative tests: a corrupt file written by hand,
//! a node killed at a line the test author chose. This module turns
//! hostility into a *reusable decorator*: [`FaultBackend`] wraps any
//! [`ChunkBackend`] and injects failures drawn from a seed-driven
//! schedule —
//!
//! * **put errors** — the `put` fails cleanly and stores nothing, the
//!   way a full or failing disk surfaces mid-write;
//! * **torn puts** — the `put` *reports success* but the stored copy is
//!   marked corrupt, the way a torn rename surfaces later through the
//!   manifest CRC: every read of that copy fails (and counts in
//!   [`ChunkBackend::read_errors`]) until the copy is overwritten,
//!   deleted, or injection is disabled;
//! * **read corruption** — a present, intact chunk fails one read
//!   (transient I/O fault), exercising the failover path that
//!   distinguishes a lost copy from an absent one;
//! * **latency spikes** — a short real sleep on selected operations,
//!   shaking out timing assumptions in concurrent tests. Spikes fire
//!   inside the backend call, i.e. in the *unlocked* I/O section of
//!   the pipelined data path (a debug assertion enforces that no
//!   store lock is held), so a fault schedule exercises genuine
//!   overlap between a slow operation and concurrent traffic instead
//!   of serializing everything behind one sleep under a lock.
//!   [`FaultSpec::delay_node`] narrows spikes to a single node, which
//!   is how the overlap tests slow one spill while asserting the rest
//!   of the store stays responsive.
//!
//! # Determinism
//!
//! Every fault decision is a **pure hash** of `(seed, operation, chunk
//! key, per-key attempt number)` — no shared RNG stream — so the
//! schedule is a function of *what* is asked, not of how threads
//! interleave. Two runs with the same seed and the same logical
//! workload inject the same faults even when the OS schedules their
//! threads differently; printing the seed is a complete repro recipe.
//! This is what lets the scenario harness ([`crate::scenario`]) and the
//! property tests promise "same seed → same schedule".
//!
//! A shared [`FaultControl`] (one per store, handed to every node's
//! decorator) counts each injected fault and carries the master enable
//! switch: scenarios run their workload with faults live, then call
//! [`FaultControl::set_enabled`]`(false)` and audit a quiet store.
//! Disabling injection also "repairs" torn copies — the decorator never
//! altered the underlying bytes, only refused to return them — so a
//! final fingerprint audit can prove the payloads underneath survived
//! the entire schedule intact.

use super::backend::{lockscope, ChunkBackend, ChunkKey};
use crate::storage::types::StorageError;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-mille fault rates plus the seed that fixes the schedule.
///
/// All rates default to zero: a default spec injects nothing and a
/// store built with it behaves exactly like the undecorated backend.
/// Rates are independent per operation; `1000` means "every time".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultSpec {
    /// Seed fixing the entire fault schedule. Same seed + same logical
    /// operation sequence → same injected faults, regardless of thread
    /// interleaving.
    pub seed: u64,
    /// Per-mille chance a `put` fails cleanly (nothing stored).
    pub put_error_permille: u16,
    /// Per-mille chance a `put` succeeds but the stored copy is marked
    /// corrupt (torn rename detected at read time).
    pub torn_put_permille: u16,
    /// Per-mille chance a read of a present chunk fails once
    /// (transient corruption / I/O error).
    pub read_error_permille: u16,
    /// Per-mille chance an operation sleeps for
    /// [`FaultSpec::delay_us`] (latency spike).
    pub delay_permille: u16,
    /// Duration of an injected latency spike, in microseconds.
    pub delay_us: u64,
    /// Restrict latency spikes to this node index (`None` = every
    /// node). Other fault classes are unaffected — this exists so a
    /// test can slow exactly one node's disk and assert the rest of
    /// the store keeps moving.
    pub delay_node: Option<usize>,
}

impl FaultSpec {
    /// Derive the node-local spec: same rates, seed mixed with the
    /// node index so two nodes never share a schedule. When
    /// [`FaultSpec::delay_node`] targets a different node, the derived
    /// spec's spike rate is zeroed — the schedule hash itself is
    /// untouched, so narrowing spikes never shifts the other fault
    /// classes' draws.
    pub fn for_node(mut self, node: usize) -> FaultSpec {
        self.seed = splitmix64(self.seed ^ (node as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        if self.delay_node.is_some_and(|n| n != node) {
            self.delay_permille = 0;
        }
        self
    }
}

/// Shared control block for one store's fault decorators: the master
/// enable switch plus counters of every injected fault. The store
/// holds one `Arc<FaultControl>` and hands a clone to each node's
/// [`FaultBackend`], so a scenario can flip injection off (for the
/// final audit) and read totals without downcasting backends.
#[derive(Debug, Default)]
pub struct FaultControl {
    enabled: AtomicBool,
    put_errors: AtomicU64,
    torn_puts: AtomicU64,
    read_errors: AtomicU64,
    delays: AtomicU64,
}

impl FaultControl {
    /// A control block with injection already enabled.
    pub fn armed() -> Arc<FaultControl> {
        let ctl = FaultControl::default();
        ctl.enabled.store(true, Ordering::SeqCst);
        Arc::new(ctl)
    }

    /// Turn injection on or off. Off means every decorator passes
    /// operations straight through (torn copies read fine again — the
    /// underlying bytes were never altered).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Is injection currently live?
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Injected clean `put` failures so far.
    pub fn put_errors(&self) -> u64 {
        self.put_errors.load(Ordering::Relaxed)
    }

    /// Injected torn puts so far.
    pub fn torn_puts(&self) -> u64 {
        self.torn_puts.load(Ordering::Relaxed)
    }

    /// Injected read failures so far (transient and torn-copy reads).
    pub fn read_errors(&self) -> u64 {
        self.read_errors.load(Ordering::Relaxed)
    }

    /// Injected latency spikes so far.
    pub fn delays(&self) -> u64 {
        self.delays.load(Ordering::Relaxed)
    }

    /// Total faults injected across all classes.
    pub fn total(&self) -> u64 {
        self.put_errors() + self.torn_puts() + self.read_errors() + self.delays()
    }
}

const OP_PUT: u8 = 1;
const OP_GET: u8 = 2;

/// SplitMix64 — the mixing function behind the schedule hash. Small,
/// statistically solid, and dependency-free.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Seed-driven fault-injecting decorator over any [`ChunkBackend`].
///
/// Thread-safe like the backends it wraps; see the module docs for the
/// fault classes and the determinism argument.
pub struct FaultBackend {
    inner: Box<dyn ChunkBackend>,
    spec: FaultSpec,
    control: Arc<FaultControl>,
    /// Keys whose stored copy a torn put marked corrupt.
    torn: Mutex<HashSet<ChunkKey>>,
    /// Per-(op, key) attempt counters: the third input to the schedule
    /// hash, so the Nth read of a key draws the same verdict in every
    /// run no matter which thread issues it.
    attempts: Mutex<HashMap<(u8, ChunkKey), u64>>,
    /// Faults injected by *this* node's decorator that surface as read
    /// errors — added to the inner backend's count so per-node
    /// `read_errors` totals stay exact.
    local_read_errors: AtomicU64,
}

impl FaultBackend {
    /// Wrap `inner`, drawing the schedule from `spec` and reporting
    /// into (and obeying the enable switch of) `control`.
    pub fn new(inner: Box<dyn ChunkBackend>, spec: FaultSpec, control: Arc<FaultControl>) -> Self {
        FaultBackend {
            inner,
            spec,
            control,
            torn: Mutex::new(HashSet::new()),
            attempts: Mutex::new(HashMap::new()),
            local_read_errors: AtomicU64::new(0),
        }
    }

    /// Advance the (op, key) attempt counter and return the schedule
    /// hash for this attempt. Always advances — even while injection
    /// is disabled — so toggling the switch never shifts later draws.
    fn draw(&self, op: u8, key: ChunkKey) -> u64 {
        let nth = {
            let mut attempts = self.attempts.lock().unwrap();
            let slot = attempts.entry((op, key)).or_insert(0);
            *slot += 1;
            *slot
        };
        let mixed = self
            .spec
            .seed
            .wrapping_add(splitmix64(((op as u64) << 56) | key.1))
            .wrapping_add(splitmix64(key.0 .0))
            .wrapping_add(splitmix64(nth));
        splitmix64(mixed)
    }

    /// Does `hash` (one schedule draw) select a fault at `permille`?
    /// Independent sub-draws come from different byte lanes of the
    /// hash so one draw can answer for several fault classes.
    fn selected(hash: u64, lane: u32, permille: u16) -> bool {
        permille > 0 && (hash.rotate_right(lane * 13) % 1000) < permille as u64
    }

    fn maybe_delay(&self, hash: u64) {
        if Self::selected(hash, 3, self.spec.delay_permille) {
            // A spike is disk time, and disk time must never run under
            // a store lock (the tentpole invariant the lock-scope
            // guard enforces across the real backends too).
            lockscope::assert_unlocked("FaultBackend::delay");
            // Count *before* sleeping: a test watching the counter can
            // detect a spike while it is still in flight — the hook the
            // overlap tests use to know a slow spill has started.
            self.control.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(self.spec.delay_us.max(1)));
        }
    }
}

impl ChunkBackend for FaultBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        let hash = self.draw(OP_PUT, key);
        if !self.control.enabled() {
            return self.inner.put(key, bytes);
        }
        self.maybe_delay(hash);
        if Self::selected(hash, 0, self.spec.put_error_permille) {
            self.control.put_errors.fetch_add(1, Ordering::Relaxed);
            return Err(StorageError::Invalid(format!(
                "injected put failure for chunk {}/{}",
                key.0 .0, key.1
            )));
        }
        self.inner.put(key, bytes)?;
        let mut torn = self.torn.lock().unwrap();
        if Self::selected(hash, 1, self.spec.torn_put_permille) {
            self.control.torn_puts.fetch_add(1, Ordering::Relaxed);
            torn.insert(key);
        } else {
            // A clean overwrite repairs an earlier torn copy.
            torn.remove(&key);
        }
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        let hash = self.draw(OP_GET, key);
        if !self.control.enabled() {
            return self.inner.get(key);
        }
        self.maybe_delay(hash);
        if self.torn.lock().unwrap().contains(&key) {
            self.control.read_errors.fetch_add(1, Ordering::Relaxed);
            self.local_read_errors.fetch_add(1, Ordering::Relaxed);
            return Err(StorageError::Invalid(format!(
                "injected torn-rename corruption for chunk {}/{}",
                key.0 .0, key.1
            )));
        }
        match self.inner.get(key)? {
            Some(bytes) => {
                if Self::selected(hash, 2, self.spec.read_error_permille) {
                    self.control.read_errors.fetch_add(1, Ordering::Relaxed);
                    self.local_read_errors.fetch_add(1, Ordering::Relaxed);
                    return Err(StorageError::Invalid(format!(
                        "injected transient read corruption for chunk {}/{}",
                        key.0 .0, key.1
                    )));
                }
                Ok(Some(bytes))
            }
            None => Ok(None),
        }
    }

    fn delete(&self, key: ChunkKey) {
        self.torn.lock().unwrap().remove(&key);
        self.inner.delete(key);
    }

    fn contains(&self, key: ChunkKey) -> bool {
        // A torn copy is present-but-unreadable, exactly like a chunk
        // file that fails its manifest CRC: `contains` says yes, `get`
        // fails. The distinction is what the failover path tests.
        self.inner.contains(key)
    }

    fn used_bytes(&self) -> u64 {
        self.inner.used_bytes()
    }

    fn chunk_count(&self) -> usize {
        self.inner.chunk_count()
    }

    fn read_errors(&self) -> u64 {
        self.inner.read_errors() + self.local_read_errors.load(Ordering::Relaxed)
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.inner.chunk_keys()
    }

    fn maintain(&self) -> bool {
        // Maintenance (e.g. segment compaction) is the inner backend's
        // business; the decorator only schedules faults on the data
        // path, so a faulted store still reclaims dead bytes.
        self.inner.maintain()
    }

    fn io_depth(&self) -> u64 {
        // The load plane must see through the decorator: a hostile
        // scenario's store still reads the real backend queue depth.
        self.inner.io_depth()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::MemoryBackend;
    use crate::storage::types::FileId;

    fn key(f: u64, c: u64) -> ChunkKey {
        (FileId(f), c)
    }

    fn backend(spec: FaultSpec) -> (FaultBackend, Arc<FaultControl>) {
        let ctl = FaultControl::armed();
        (
            FaultBackend::new(Box::<MemoryBackend>::default(), spec, Arc::clone(&ctl)),
            ctl,
        )
    }

    /// Same seed → identical injected-fault schedule, independent of
    /// how calls interleave with other keys.
    #[test]
    fn schedule_is_a_pure_function_of_seed_and_attempt() {
        let spec = FaultSpec {
            seed: 42,
            put_error_permille: 300,
            read_error_permille: 300,
            ..FaultSpec::default()
        };
        let trace = |interleave: bool| {
            let (fb, _ctl) = backend(spec);
            let mut out = Vec::new();
            for n in 0..50u64 {
                if interleave {
                    // Touch unrelated keys between draws; must not
                    // perturb key(1, 0)'s schedule.
                    let _ = fb.put(key(99, n), b"noise");
                }
                out.push(fb.put(key(1, 0), b"x").is_err());
                out.push(fb.get(key(1, 0)).is_err());
            }
            out
        };
        assert_eq!(trace(false), trace(true));
    }

    #[test]
    fn put_error_stores_nothing() {
        let spec = FaultSpec {
            seed: 7,
            put_error_permille: 1000,
            ..FaultSpec::default()
        };
        let (fb, ctl) = backend(spec);
        assert!(fb.put(key(1, 0), b"payload").is_err());
        assert!(!fb.contains(key(1, 0)));
        assert_eq!(fb.used_bytes(), 0);
        assert_eq!(ctl.put_errors(), 1);
    }

    #[test]
    fn torn_put_reports_success_but_reads_fail_until_disabled() {
        let spec = FaultSpec {
            seed: 7,
            torn_put_permille: 1000,
            ..FaultSpec::default()
        };
        let (fb, ctl) = backend(spec);
        fb.put(key(1, 0), b"payload").expect("torn put reports ok");
        assert!(fb.contains(key(1, 0)), "torn copy is present-but-bad");
        assert!(fb.get(key(1, 0)).is_err());
        assert!(fb.get(key(1, 0)).is_err(), "torn corruption persists");
        assert_eq!(ctl.torn_puts(), 1);
        assert_eq!(ctl.read_errors(), 2);
        assert_eq!(fb.read_errors(), 2);
        // Disabling injection repairs the copy: bytes were intact all
        // along.
        ctl.set_enabled(false);
        assert_eq!(fb.get(key(1, 0)).unwrap().as_deref(), Some(&b"payload"[..]));
    }

    #[test]
    fn transient_read_error_fires_once_per_selected_attempt() {
        let spec = FaultSpec {
            seed: 3,
            read_error_permille: 500,
            ..FaultSpec::default()
        };
        let (fb, ctl) = backend(spec);
        fb.put(key(2, 1), b"abc").unwrap();
        let mut errs = 0u64;
        for _ in 0..40 {
            match fb.get(key(2, 1)) {
                Ok(Some(b)) => assert_eq!(b, b"abc"),
                Ok(None) => panic!("chunk vanished"),
                Err(_) => errs += 1,
            }
        }
        assert!(errs > 0, "a 50% rate over 40 reads must fire");
        assert!(errs < 40, "and must not fire every time");
        assert_eq!(ctl.read_errors(), errs);
    }

    #[test]
    fn disabled_control_passes_everything_through() {
        let spec = FaultSpec {
            seed: 9,
            put_error_permille: 1000,
            torn_put_permille: 1000,
            read_error_permille: 1000,
            ..FaultSpec::default()
        };
        let (fb, ctl) = backend(spec);
        ctl.set_enabled(false);
        fb.put(key(4, 0), b"quiet").unwrap();
        assert_eq!(fb.get(key(4, 0)).unwrap().as_deref(), Some(&b"quiet"[..]));
        assert_eq!(ctl.total(), 0);
    }
}
