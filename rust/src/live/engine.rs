//! Live workflow execution over [`LiveStore`] + the kernel runtime.
//!
//! Executes the same [`Workflow`] DAGs the simulator runs, but for real:
//! a worker pool of std threads claims ready tasks, the location-aware
//! policy places each task on the node holding its inputs (queried
//! through the `location` attribute — the bottom-up channel), inputs are
//! read as bytes, the task body runs the compute kernels (stage
//! transform for 1-input tasks, 8-way reduce merge for fan-in tasks),
//! and outputs are written back with the workload's hints (top-down
//! channel).

use crate::hints::{AccessPattern, Hint, Lifetime, TagSet};
use crate::runtime::{self, Runtime};
use crate::storage::types::NodeId;
use crate::workflow::dag::{Tier, Workflow};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::proto::{ManagerInfo, ManagerService, StoreCounters};
use super::rpc::RemoteStore;
use super::store::{CacheStats, LiveStore};
use crate::storage::types::StorageError;

/// Engine-side cross-layer options.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineOptions {
    /// Tag every consumed intermediate output with `Lifetime=scratch` +
    /// `Consumers=<n>` derived from the DAG (top-down channel), so a
    /// store with [`crate::live::LiveTuning::lifetime`] reclaims it
    /// after its last read. Outputs that already carry an explicit
    /// `Lifetime` tag are left alone.
    pub lifetime: bool,
    /// Ask the store to promote `Pattern=pipeline` inputs into the
    /// executing node's cache ahead of the reads (no-op without a
    /// cache tier).
    pub prefetch: bool,
}

/// The engine's grip on a store: the in-process [`LiveStore`] (the
/// default transport — plain method calls, trace-equivalent to the
/// pre-split monolith) or a [`RemoteStore`] client framing every call
/// to a `woss managerd` daemon. Both arms implement
/// [`ManagerService`], so the engine, scenario harness, and CLI drive
/// either transport through one code path.
#[derive(Clone)]
pub enum StoreHandle {
    /// In-process store — direct method calls, no serialization.
    Local(Arc<LiveStore>),
    /// Socket client to a `woss managerd` daemon.
    Remote(Arc<RemoteStore>),
}

impl StoreHandle {
    /// The typed service surface (both transports implement it).
    pub fn svc(&self) -> &dyn ManagerService {
        match self {
            StoreHandle::Local(s) => s.as_ref(),
            StoreHandle::Remote(s) => s.as_ref(),
        }
    }

    /// The in-process store, when this handle holds one (`None` over a
    /// socket — process-local surfaces like `audit` live on the
    /// manager's side of the wire).
    pub fn local(&self) -> Option<&LiveStore> {
        match self {
            StoreHandle::Local(s) => Some(s),
            StoreHandle::Remote(_) => None,
        }
    }

    /// Static deployment facts (the remote side caches its `Hello`).
    pub fn info(&self) -> ManagerInfo {
        self.svc().hello()
    }

    fn n_nodes(&self) -> usize {
        self.info().n_nodes
    }
    fn exposes_location(&self) -> bool {
        self.info().exposes_location
    }
    fn adaptive(&self) -> bool {
        self.info().adaptive
    }
    fn cache_enabled(&self) -> bool {
        self.info().cache_enabled
    }
    fn lifetime_enabled(&self) -> bool {
        self.info().lifetime_enabled
    }
    fn write_file(
        &self,
        node: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> std::result::Result<(), StorageError> {
        self.svc().write_file(node, path, data, tags)
    }
    fn read_file(&self, node: NodeId, path: &str) -> std::result::Result<Vec<u8>, StorageError> {
        self.svc().read_file(node, path)
    }
    fn set_xattr(&self, path: &str, key: &str, value: &str) {
        self.svc().set_attr(path, key, value)
    }
    fn get_xattr(&self, path: &str, key: &str) -> Option<String> {
        self.svc().get_attr(path, key)
    }
    fn file_size(&self, path: &str) -> Option<u64> {
        self.svc().file_size(path)
    }
    fn locations(&self, path: &str) -> Vec<NodeId> {
        self.svc().locations(path)
    }
    fn prefetch(&self, node: NodeId, path: &str) -> std::result::Result<usize, StorageError> {
        self.svc().prefetch(node, path)
    }
    fn node_read_cost(&self, node: NodeId) -> f64 {
        self.svc().node_read_cost(node)
    }
    fn flush_replication(&self) {
        self.svc().flush()
    }
    fn cache_stats(&self) -> CacheStats {
        self.svc().cache_stats()
    }
    fn counters(&self) -> StoreCounters {
        self.svc().counters()
    }
}

/// Wrapper serializing kernel execution across the worker pool: the
/// example workloads are storage-bound, so a single compute lane is an
/// acceptable simplification (measured and reported by the e2e example).
struct SharedRuntime(Mutex<Runtime>);

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// Wall-clock makespan.
    pub elapsed_secs: f64,
    /// Tasks executed.
    pub tasks: usize,
    /// Bytes written to the store.
    pub bytes_written: u64,
    /// Bytes read from the store.
    pub bytes_read: u64,
    /// Chunk reads served node-locally.
    pub local_reads: u64,
    /// Chunk reads served remotely.
    pub remote_reads: u64,
    /// Replica chunk copies drained by the background replication pool
    /// (optimistic `RepSmntc`); the run flushes before reporting, so
    /// every deferred copy has landed by the time this is read.
    pub bg_replicas: u64,
    /// Chunk reads served from the hot-chunk cache tier (0 when the
    /// tier is disabled).
    pub cache_hits: u64,
    /// Chunks promoted into consumer caches by the prefetch path.
    pub prefetched_chunks: u64,
    /// Dirty (cache-only `Lifetime=scratch`) chunks the disk backend
    /// had to write back under eviction pressure; 0 on the memory
    /// backend or when every scratch chunk died cache-resident.
    pub spilled_chunks: u64,
    /// Chunk backend the store ran on (`mem` | `disk` | `seg`).
    pub backend: &'static str,
    /// Chunk reads that failed on a present chunk (disk fault /
    /// corruption, counted per backend) — reads failed over to another
    /// holder, but the faults are a first-class signal now, not
    /// silent remote traffic. Always 0 on the memory backend.
    pub read_errors: u64,
    /// Files that survived a [`LiveStore::reopen`] into the store this
    /// run executed on (0 for a fresh store).
    pub recovered_files: u64,
    /// End-of-run replication barriers that hit their
    /// [`crate::live::LiveTuning::flush_timeout_ms`] deadline instead
    /// of draining (always 0 with the deadline off — the default).
    pub flush_timeouts: u64,
    /// Highest bytes resident in any single node's cache over the run
    /// — bounded by the configured per-node budget.
    pub peak_cache_bytes: u64,
    /// Scratch intermediates the store reclaimed after their last
    /// declared consumer read (lifetime enforcement).
    pub files_reclaimed: u64,
    /// Logical bytes freed by that reclamation — the run's working-set
    /// saving.
    pub bytes_reclaimed: u64,
    /// Foreground per-chunk put latency percentiles, µs (primary-copy
    /// landing inside [`LiveStore::write_file`]; 0.0 when no puts ran).
    pub put_p50_us: f64,
    /// See [`LiveReport::put_p50_us`].
    pub put_p95_us: f64,
    /// See [`LiveReport::put_p50_us`].
    pub put_p99_us: f64,
    /// Foreground per-chunk read latency percentiles, µs (chunk serve
    /// inside [`LiveStore::read_file`]; 0.0 when no reads ran).
    pub get_p50_us: f64,
    /// See [`LiveReport::get_p50_us`].
    pub get_p95_us: f64,
    /// See [`LiveReport::get_p50_us`].
    pub get_p99_us: f64,
    /// Dirty write-back (spill) latency percentiles, µs — the disk
    /// writes the cache tier runs through the I/O pool; 0.0 when
    /// nothing spilled.
    pub spill_p50_us: f64,
    /// See [`LiveReport::spill_p50_us`].
    pub spill_p95_us: f64,
    /// See [`LiveReport::spill_p50_us`].
    pub spill_p99_us: f64,
    /// Kernel executions by artifact name.
    pub kernel_execs: BTreeMap<String, u64>,
    /// Fingerprint of every produced file (path → checksum of first
    /// tile), for end-to-end integrity verification.
    pub fingerprints: BTreeMap<String, f32>,
}

impl LiveReport {
    /// Fraction of chunk reads served locally.
    pub fn locality(&self) -> f64 {
        let total = self.local_reads + self.remote_reads;
        if total == 0 {
            0.0
        } else {
            self.local_reads as f64 / total as f64
        }
    }

    /// Aggregate storage throughput (read+write bytes over makespan).
    pub fn throughput_mbps(&self) -> f64 {
        (self.bytes_written + self.bytes_read) as f64 / (1024.0 * 1024.0)
            / self.elapsed_secs.max(1e-9)
    }
}

/// The live engine.
pub struct LiveEngine {
    store: StoreHandle,
    runtime: Arc<SharedRuntime>,
    workers: usize,
    options: EngineOptions,
    /// Fixed kernel parameters (weights/bias tiles), deterministic.
    w: Arc<Vec<f32>>,
    b: Arc<Vec<f32>>,
}

struct RunState {
    remaining: Vec<usize>,
    ready: Vec<usize>,
    done: usize,
    failed: Option<String>,
}

impl LiveEngine {
    /// Build an engine over `store` with `workers` threads and default
    /// [`EngineOptions`] (no lifetime tagging, no prefetch). Kernel
    /// artifacts in the default directory, if any, are validated; the
    /// interpreted backend runs regardless (see [`crate::runtime`]).
    pub fn new(store: LiveStore, workers: usize) -> Result<Self> {
        LiveEngine::with_options(store, workers, EngineOptions::default())
    }

    /// Build an engine with explicit cross-layer [`EngineOptions`].
    pub fn with_options(store: LiveStore, workers: usize, options: EngineOptions) -> Result<Self> {
        LiveEngine::with_handle(StoreHandle::Local(Arc::new(store)), workers, options)
    }

    /// Build an engine over either transport — the socket path hands a
    /// [`StoreHandle::Remote`] here and everything downstream (the
    /// workloads, the scenario harness, the CLI reports) runs
    /// unchanged.
    pub fn with_handle(
        store: StoreHandle,
        workers: usize,
        options: EngineOptions,
    ) -> Result<Self> {
        let rt = Runtime::load(&Runtime::artifact_dir())?;
        Ok(LiveEngine {
            store,
            runtime: Arc::new(SharedRuntime(Mutex::new(rt))),
            workers: workers.max(1),
            options,
            w: Arc::new(param_tile(101, 0.02)),
            b: Arc::new(param_tile(102, 0.05)),
        })
    }

    /// The in-process store (counters, verification, shutdown).
    ///
    /// # Panics
    /// When the engine runs over a socket transport — use
    /// [`LiveEngine::handle`] there.
    pub fn store(&self) -> &LiveStore {
        self.store
            .local()
            .expect("engine is driving a remote store; use handle()")
    }

    /// The transport-agnostic store handle.
    pub fn handle(&self) -> &StoreHandle {
        &self.store
    }

    /// Execute `workflow` to completion; every task really moves bytes
    /// and runs kernels. Backend-tier reads/writes are served by the
    /// store too (a directory prefix separates tiers).
    pub fn run(&self, workflow: &Workflow) -> Result<LiveReport> {
        workflow.validate().map_err(|e| anyhow!(e))?;

        // Materialize backend preloads with deterministic bytes,
        // round-robin across the nodes: funnelling every preload
        // through node 0 serialized multi-node runs on node 0's locks
        // and capacity (and made it the stage-in hot-spot).
        let n_nodes = self.store.n_nodes().max(1);
        for (i, (path, size)) in workflow.backend_preload.iter().enumerate() {
            let data = synth_bytes(path, *size);
            self.store
                .write_file(NodeId(i % n_nodes), path, &data, &TagSet::new())
                .map_err(|e| anyhow!("preload {path}: {e}"))?;
        }

        let deps = workflow.dependencies();
        let mut rdeps: Vec<Vec<usize>> = vec![Vec::new(); workflow.tasks.len()];
        for (b, ds) in deps.iter().enumerate() {
            for &a in ds {
                rdeps[a].push(b);
            }
        }
        let state = Mutex::new(RunState {
            remaining: deps.iter().map(BTreeSet::len).collect(),
            ready: (0..workflow.tasks.len())
                .filter(|&i| deps[i].is_empty())
                .collect(),
            done: 0,
            failed: None,
        });
        let cv = Condvar::new();
        let rdeps = &rdeps;
        let next_node = AtomicUsize::new(0);
        // Tasks currently executing per node — the load signal that
        // breaks placement ties (holder order never was one).
        let node_load: Vec<AtomicUsize> = (0..n_nodes).map(|_| AtomicUsize::new(0)).collect();
        let node_load = &node_load;
        let fingerprints = Mutex::new(BTreeMap::new());
        // Lifetime tagging (top-down channel): the DAG knows exactly
        // how many reads each intermediate will see; declare that to
        // the store so it can reclaim scratch data after the last one.
        let consumers = if self.options.lifetime {
            workflow.consumer_counts()
        } else {
            BTreeMap::new()
        };
        let consumers = &consumers;
        let start = Instant::now();

        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                scope.spawn(|| {
                    loop {
                        // Claim a ready task or exit when all are done.
                        let task_id = {
                            let mut st = state.lock().unwrap();
                            loop {
                                if st.failed.is_some() || st.done == workflow.tasks.len() {
                                    cv.notify_all();
                                    return;
                                }
                                if let Some(id) = st.ready.pop() {
                                    break id;
                                }
                                st = cv.wait(st).unwrap();
                            }
                        };
                        let result = self.execute_task(
                            workflow,
                            task_id,
                            &next_node,
                            node_load,
                            &fingerprints,
                            consumers,
                        );
                        let mut st = state.lock().unwrap();
                        match result {
                            Ok(()) => {
                                st.done += 1;
                                for &b in &rdeps[task_id] {
                                    st.remaining[b] -= 1;
                                    if st.remaining[b] == 0 {
                                        st.ready.push(b);
                                    }
                                }
                            }
                            Err(e) => st.failed = Some(format!("task {task_id}: {e}")),
                        }
                        cv.notify_all();
                    }
                });
            }
        });

        let st = state.into_inner().unwrap();
        if let Some(err) = st.failed {
            return Err(anyhow!(err));
        }
        // Replication barrier: optimistic writes returned after their
        // primary copy; a completed run leaves every file at its full
        // replica count (and the makespan pays for it, keeping the
        // optimistic-vs-pessimistic comparison honest).
        self.store.flush_replication();
        let rt = self.runtime.0.lock().unwrap();
        let kernel_execs = runtime::ARTIFACTS
            .iter()
            .map(|&n| (n.to_string(), rt.exec_count(n)))
            .collect();
        let cache = self.store.cache_stats();
        // One counters() snapshot serves both transports — over a
        // socket these were never process-local atomics to read.
        let counters = self.store.counters();
        Ok(LiveReport {
            elapsed_secs: start.elapsed().as_secs_f64(),
            tasks: workflow.tasks.len(),
            bytes_written: counters.bytes_written,
            bytes_read: counters.bytes_read,
            local_reads: counters.local_reads,
            remote_reads: counters.remote_reads,
            bg_replicas: counters.background_copies,
            cache_hits: cache.hits,
            prefetched_chunks: cache.prefetched,
            spilled_chunks: cache.spilled,
            backend: self.store.info().backend.label(),
            read_errors: cache.read_errors,
            recovered_files: counters.recovered_files,
            flush_timeouts: counters.flush_timeouts,
            peak_cache_bytes: cache.peak_node_resident,
            files_reclaimed: cache.files_reclaimed,
            bytes_reclaimed: cache.bytes_reclaimed,
            put_p50_us: cache.put_p50_us,
            put_p95_us: cache.put_p95_us,
            put_p99_us: cache.put_p99_us,
            get_p50_us: cache.get_p50_us,
            get_p95_us: cache.get_p95_us,
            get_p99_us: cache.get_p99_us,
            spill_p50_us: cache.spill_p50_us,
            spill_p95_us: cache.spill_p95_us,
            spill_p99_us: cache.spill_p99_us,
            kernel_execs,
            fingerprints: fingerprints.into_inner().unwrap(),
        })
    }

    fn execute_task(
        &self,
        workflow: &Workflow,
        task_id: usize,
        next_node: &AtomicUsize,
        node_load: &[AtomicUsize],
        fingerprints: &Mutex<BTreeMap<String, f32>>,
        consumers: &BTreeMap<String, u32>,
    ) -> Result<()> {
        let task = &workflow.tasks[task_id];

        // --- location-aware placement (bottom-up channel) ---
        let node = if self.store.exposes_location() {
            // Gravity per holder: the total input bytes it serves
            // node-locally. The size is looked up once per input (it
            // was re-queried inside the holder loop), and ties break
            // toward the currently least-loaded node, then the lowest
            // id for determinism — a holder's position in the
            // `locations()` list is placement order, not a load signal.
            let mut gravity: BTreeMap<usize, u64> = BTreeMap::new();
            for read in &task.reads {
                // Charge the real getxattr("location") op like the
                // integration does.
                let _ = self.store.get_xattr(&read.path, crate::hints::LOCATION_ATTR);
                let bytes = self.store.file_size(&read.path).unwrap_or(0);
                for holder in self.store.locations(&read.path) {
                    *gravity.entry(holder.0).or_insert(0) += bytes;
                }
            }
            let picked = if self.store.adaptive() {
                // Adaptive stores break byte-ties with the same
                // read-cost score `read_file` uses to order holders,
                // so a node mid-spill or mid-compaction stops
                // attracting tasks its data-gravity alone would pull
                // in. The remaining tie-breaks keep the static
                // ordering for determinism.
                gravity.into_iter().max_by(|&(a, a_bytes), &(b, b_bytes)| {
                    a_bytes
                        .cmp(&b_bytes)
                        .then_with(|| {
                            self.store
                                .node_read_cost(NodeId(b))
                                .partial_cmp(&self.store.node_read_cost(NodeId(a)))
                                .unwrap_or(std::cmp::Ordering::Equal)
                        })
                        .then_with(|| {
                            node_load[b]
                                .load(Ordering::Relaxed)
                                .cmp(&node_load[a].load(Ordering::Relaxed))
                        })
                        .then_with(|| b.cmp(&a))
                })
            } else {
                gravity.into_iter().max_by_key(|&(n, bytes)| {
                    (
                        bytes,
                        std::cmp::Reverse(node_load[n].load(Ordering::Relaxed)),
                        std::cmp::Reverse(n),
                    )
                })
            };
            picked
                .map(|(n, _)| NodeId(n))
                .unwrap_or_else(|| {
                    NodeId(next_node.fetch_add(1, Ordering::Relaxed) % self.store.n_nodes())
                })
        } else {
            NodeId(next_node.fetch_add(1, Ordering::Relaxed) % self.store.n_nodes())
        };
        node_load[node.0].fetch_add(1, Ordering::Relaxed);
        let result = self.run_task_on(workflow, task_id, node, fingerprints, consumers);
        node_load[node.0].fetch_sub(1, Ordering::Relaxed);
        result
    }

    /// Body of one task on its chosen node: tag outputs, warm the
    /// cache, read inputs, run the kernels, write outputs.
    fn run_task_on(
        &self,
        workflow: &Workflow,
        task_id: usize,
        node: NodeId,
        fingerprints: &Mutex<BTreeMap<String, f32>>,
        consumers: &BTreeMap<String, u32>,
    ) -> Result<()> {
        let task = &workflow.tasks[task_id];

        // --- tag outputs (top-down channel) ---
        for write in &task.writes {
            for (k, v) in write.tags.iter() {
                self.store.set_xattr(&write.path, k, v);
            }
            // Lifetime tagging: consumed intermediates become declared
            // scratch — unless the workload already chose a lifetime
            // or declared its own consumer count (e.g. readers beyond
            // the DAG), which must never be clobbered.
            if self.engine_tags_scratch(write) {
                if let Some(n) = consumers.get(&write.path) {
                    self.store
                        .set_xattr(&write.path, crate::hints::keys::LIFETIME, "scratch");
                    self.store
                        .set_xattr(&write.path, crate::hints::keys::CONSUMERS, &n.to_string());
                }
            }
        }

        // --- prefetch pipeline inputs (cache tier warm-up) ---
        if self.options.prefetch && self.store.cache_enabled() {
            for read in &task.reads {
                if read.tier != Tier::Intermediate {
                    continue;
                }
                // The typed grammar owns Pattern parsing — a raw
                // string compare here would drift from the store's
                // cache_class as the grammar evolves.
                let pipeline = self
                    .store
                    .get_xattr(&read.path, crate::hints::keys::PATTERN)
                    .map(|v| {
                        matches!(
                            crate::hints::parse(crate::hints::keys::PATTERN, &v),
                            Hint::Pattern(AccessPattern::Pipeline)
                        )
                    })
                    .unwrap_or(false);
                if pipeline {
                    // Best-effort warm-up; the read path below is
                    // correct with or without the promotion landing.
                    let _ = self.store.prefetch(node, &read.path);
                }
            }
        }

        // --- read inputs ---
        let mut input_tiles: Vec<Vec<f32>> = Vec::new();
        for read in &task.reads {
            let bytes = self.store.read_file(node, &read.path)?;
            let mut tiles = runtime::bytes_to_tiles(&bytes);
            input_tiles.push(tiles.swap_remove(0));
        }

        // --- compute: the task body runs real kernels ---
        let out_tile = if input_tiles.len() >= 2 {
            // Fan-in task: 8-way reduce merge (pad by cycling inputs).
            let mut parts = Vec::with_capacity(runtime::MERGE_K * runtime::TILE_ELEMS);
            for k in 0..runtime::MERGE_K {
                parts.extend(&input_tiles[k % input_tiles.len()]);
            }
            let weights = vec![1.0f32 / runtime::MERGE_K as f32; runtime::MERGE_K];
            let mut rt = self.runtime.0.lock().unwrap();
            rt.reduce_merge(&parts, &weights)?
        } else if let Some(x) = input_tiles.first() {
            let mut rt = self.runtime.0.lock().unwrap();
            rt.stage_transform(x, &self.w, &self.b)?
        } else {
            // Source task: synthesize a tile.
            runtime::bytes_to_tiles(&synth_bytes(&task.stage, 1024)).swap_remove(0)
        };

        // --- write outputs ---
        for write in &task.writes {
            let data = tile_to_bytes(&out_tile, write.size);
            // Tags already set via set_xattr (pending), write plain.
            self.store
                .write_file(node, &write.path, &data, &TagSet::new())?;
            // Fingerprint outputs for end-of-run verification — except
            // files the store will actually reclaim after their last
            // consumer, which verify() could never re-read (transience
            // is the point). Anything that survives the run — explicit
            // durable tags, engine lifetime off, store enforcement off
            // — stays covered.
            let transient = self.will_be_reclaimed(write, consumers);
            if write.tier == Tier::Intermediate && !transient {
                let tiles = runtime::bytes_to_tiles(&data);
                let mut rt = self.runtime.0.lock().unwrap();
                let fp = rt.checksum(&tiles[0])?;
                fingerprints.lock().unwrap().insert(write.path.clone(), fp);
            }
        }
        Ok(())
    }

    /// Would this engine stamp `write` with `Lifetime=scratch` +
    /// `Consumers`? Only when lifetime tagging is on, the output is an
    /// intermediate, and the workload declared neither a lifetime nor
    /// its own consumer count.
    fn engine_tags_scratch(&self, write: &crate::workflow::dag::WriteSpec) -> bool {
        self.options.lifetime
            && write.tier == Tier::Intermediate
            && write.tags.get(crate::hints::keys::LIFETIME).is_none()
            && write.tags.get(crate::hints::keys::CONSUMERS).is_none()
    }

    /// Will the store reclaim this output before the run ends? True
    /// only when enforcement is actually active (store lifetime knob +
    /// hints-enabled registry — a DSS baseline never reclaims) and the
    /// effective tags declare scratch with a consumer count: either
    /// the engine is about to stamp them, or the workload authored
    /// them itself.
    fn will_be_reclaimed(
        &self,
        write: &crate::workflow::dag::WriteSpec,
        consumers: &BTreeMap<String, u32>,
    ) -> bool {
        if !self.store.lifetime_enabled() || !self.store.exposes_location() {
            return false; // no enforcement / DSS: tags are inert
        }
        let engine_tagged =
            self.engine_tags_scratch(write) && consumers.contains_key(&write.path);
        let workload_tagged =
            write.tags.lifetime() == Lifetime::Scratch && write.tags.consumers().is_some();
        engine_tagged || workload_tagged
    }

    /// Re-read every fingerprinted file and verify its checksum — the
    /// end-to-end integrity check the e2e example reports.
    pub fn verify(&self, report: &LiveReport) -> Result<usize> {
        self.verify_fingerprints(&report.fingerprints)
    }

    /// Verify an explicit path → fingerprint map against the store.
    /// This is the restart gate's workhorse: a run records its
    /// fingerprints (e.g. `woss live --fingerprint-file`), the store
    /// is re-opened in a fresh process, and every recovered file must
    /// still hash to what the dead process wrote.
    pub fn verify_fingerprints(&self, fingerprints: &BTreeMap<String, f32>) -> Result<usize> {
        let mut verified = 0;
        for (path, &want) in fingerprints {
            let bytes = self.store.read_file(NodeId(0), path)?;
            let tiles = runtime::bytes_to_tiles(&bytes);
            let got = {
                let mut rt = self.runtime.0.lock().unwrap();
                rt.checksum(&tiles[0])?
            };
            let tol = want.abs().max(1.0) * 1e-4;
            if (got - want).abs() > tol {
                return Err(anyhow!(
                    "integrity failure on {path}: wrote {want}, read back {got}"
                ));
            }
            verified += 1;
        }
        Ok(verified)
    }
}

/// Deterministic pseudo-random bytes for a path.
fn synth_bytes(path: &str, size: u64) -> Vec<u8> {
    let seed = path.bytes().fold(0u64, |acc, b| {
        acc.wrapping_mul(31).wrapping_add(b as u64)
    });
    let mut rng = crate::util::Rng::new(seed);
    (0..size).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
}

/// Serialize a tile back to `size` bytes (repeat/truncate).
fn tile_to_bytes(tile: &[f32], size: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(size as usize);
    'outer: loop {
        for v in tile {
            let quant = ((v.abs() * 1.0e6) as u32).to_le_bytes();
            for b in quant {
                if out.len() as u64 >= size {
                    break 'outer;
                }
                out.push(b);
            }
        }
        if tile.is_empty() {
            break;
        }
    }
    out
}

/// Deterministic parameter tile.
fn param_tile(seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = crate::util::Rng::new(seed);
    (0..runtime::TILE_ELEMS)
        .map(|_| (rng.gen_f64() as f32 - 0.5) * 2.0 * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::dag::TaskSpec;

    /// The full live tests move megabytes through debug-build kernels;
    /// gate them behind the artifact build so `cargo test` stays fast.
    fn artifacts_present() -> bool {
        Runtime::artifact_dir()
            .join("stage_transform.hlo.txt")
            .exists()
    }

    #[test]
    fn tiny_live_run_executes_kernels() {
        // Ungated smoke: one source + one transform task through the
        // interpreted backend, bytes and counters verified.
        let mut w = Workflow::new();
        w.preload("/backend/in", 200_000);
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/in", Tier::Backend)
                .write("/w/in", Tier::Intermediate, 150_000, TagSet::from_pairs([("DP", "local")])),
        );
        w.push(
            TaskSpec::new(0, "s1")
                .read("/w/in", Tier::Intermediate)
                .write("/w/out", Tier::Intermediate, 100_000, TagSet::new()),
        );
        let engine = LiveEngine::new(LiveStore::woss(3), 2).unwrap();
        let report = engine.run(&w).unwrap();
        assert_eq!(report.tasks, 2);
        assert!(report.bytes_written > 0);
        assert!(report.kernel_execs["stage_transform"] >= 1);
        let verified = engine.verify(&report).unwrap();
        assert_eq!(verified, report.fingerprints.len());
        assert!(verified >= 2);
    }

    #[test]
    fn lifetime_mode_reclaims_consumed_intermediates() {
        // Ungated smoke: with lifetime tagging on (engine) and
        // enforcement on (store), the consumed intermediate is gone
        // after the run, the final output survives, and verification
        // still passes (scratch files are not fingerprinted).
        use crate::live::store::LiveTuning;
        let mut w = Workflow::new();
        w.preload("/backend/in", 200_000);
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/in", Tier::Backend)
                .write("/w/in", Tier::Intermediate, 150_000, TagSet::from_pairs([("DP", "local")])),
        );
        w.push(
            TaskSpec::new(0, "s1")
                .read("/w/in", Tier::Intermediate)
                .write("/w/out", Tier::Intermediate, 100_000, TagSet::new()),
        );
        let store = LiveStore::woss_with(
            3,
            LiveTuning {
                cache_bytes: Some(4 << 20),
                lifetime: true,
                ..LiveTuning::default()
            },
        );
        let engine = LiveEngine::with_options(
            store,
            2,
            EngineOptions {
                lifetime: true,
                prefetch: true,
            },
        )
        .unwrap();
        let report = engine.run(&w).unwrap();
        assert_eq!(report.tasks, 2);
        assert_eq!(report.files_reclaimed, 1, "/w/in died after its only read");
        assert_eq!(report.bytes_reclaimed, 150_000);
        assert!(engine.store().file_size("/w/in").is_none(), "reclaimed");
        assert!(engine.store().file_size("/w/out").is_some(), "output survives");
        assert!(report.fingerprints.contains_key("/w/out"));
        assert!(!report.fingerprints.contains_key("/w/in"));
        let verified = engine.verify(&report).unwrap();
        assert_eq!(verified, report.fingerprints.len());
    }

    fn small_workflow() -> Workflow {
        let mut w = Workflow::new();
        w.preload("/backend/in", 600_000);
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/in", Tier::Backend)
                .write("/w/in", Tier::Intermediate, 600_000, TagSet::from_pairs([("DP", "local")])),
        );
        for p in 0..3 {
            w.push(
                TaskSpec::new(0, "s1")
                    .read("/w/in", Tier::Intermediate)
                    .write(&format!("/w/mid{p}"), Tier::Intermediate, 400_000, TagSet::from_pairs([("DP", "local")])),
            );
        }
        let mut merge = TaskSpec::new(0, "merge");
        for p in 0..3 {
            merge = merge.read(&format!("/w/mid{p}"), Tier::Intermediate);
        }
        merge = merge.write("/w/out", Tier::Intermediate, 300_000, TagSet::new());
        w.push(merge);
        w
    }

    #[test]
    fn live_run_completes_and_verifies() {
        if !artifacts_present() {
            eprintln!("artifacts missing; skipping live engine test");
            return;
        }
        let engine = LiveEngine::new(LiveStore::woss(4), 4).unwrap();
        let report = engine.run(&small_workflow()).unwrap();
        assert_eq!(report.tasks, 5);
        assert!(report.bytes_written > 0);
        assert!(report.kernel_execs["stage_transform"] >= 3);
        assert!(report.kernel_execs["reduce_merge"] >= 1);
        let verified = engine.verify(&report).unwrap();
        assert_eq!(verified, report.fingerprints.len());
        assert!(verified >= 5, "in + 3 mids + out fingerprinted");
    }

    #[test]
    fn live_locality_improves_with_hints() {
        if !artifacts_present() {
            return;
        }
        let woss = LiveEngine::new(LiveStore::woss(4), 4).unwrap();
        let rw = woss.run(&small_workflow()).unwrap();
        let dss = LiveEngine::new(LiveStore::dss(4), 4).unwrap();
        let rd = dss.run(&small_workflow()).unwrap();
        assert!(
            rw.locality() > rd.locality(),
            "WOSS locality {:.2} must beat DSS {:.2}",
            rw.locality(),
            rd.locality()
        );
    }
}
