//! Experiment harness: one entry per paper figure/table.
//!
//! [`SystemKind`] enumerates the storage configurations the paper
//! compares; [`execute`] deploys one over a fresh simulated cluster and
//! runs a workflow through it; [`repeat`] averages seeded repetitions
//! (the paper averages 4–20 runs). The per-figure drivers live in
//! [`experiments`] and are reachable via `woss experiment <id>` and the
//! `cargo bench` targets.

pub mod experiments;

use crate::gpfs::Gpfs;
use crate::nfs::NfsServer;
use crate::sim::{Calib, Cluster, DiskKind};
use crate::storage::model::StorageModel;
use crate::storage::{standard_deployment, LocalFs};
use crate::util::Summary;
use crate::workflow::engine::{run_workflow, EngineConfig, RunResult};
use crate::workflow::scheduler::{LeastLoaded, LocationAware, ProbeLocation, Scheduler};
use crate::workflow::Workflow;

/// Which persistent backend serves stage-in/out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The single NFS server (cluster testbed).
    Nfs,
    /// The GPFS I/O-server pool (BG/P testbed).
    Gpfs,
}

/// A storage configuration under test (one bar/line in a figure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// Workflow runs directly against the NFS server (no intermediate).
    Nfs,
    /// DSS baseline over spinning disks.
    DssDisk,
    /// DSS baseline over RAM-disks.
    DssRam,
    /// WOSS over spinning disks.
    WossDisk,
    /// WOSS over RAM-disks.
    WossRam,
    /// Node-local RAM-disk file system (pipeline best case).
    LocalRam,
    /// Workflow runs directly against GPFS (BG/P backend baseline).
    GpfsOnly,
}

impl SystemKind {
    /// Display label matching the paper's figures.
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::Nfs => "NFS",
            SystemKind::DssDisk => "DSS-DISK",
            SystemKind::DssRam => "DSS-RAM",
            SystemKind::WossDisk => "WOSS-DISK",
            SystemKind::WossRam => "WOSS-RAM",
            SystemKind::LocalRam => "local",
            SystemKind::GpfsOnly => "GPFS",
        }
    }

    fn disk_kind(&self) -> DiskKind {
        match self {
            SystemKind::DssDisk | SystemKind::WossDisk => DiskKind::Spinning,
            _ => DiskKind::RamDisk,
        }
    }

    fn is_woss(&self) -> bool {
        matches!(self, SystemKind::WossDisk | SystemKind::WossRam)
    }
}

/// One experiment run specification.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Storage configuration under test.
    pub system: SystemKind,
    /// Cluster nodes including the manager node.
    pub nodes: usize,
    /// Persistent backend serving stage-in/out.
    pub backend: Backend,
    /// Testbed calibration.
    pub calib: Calib,
    /// Base RNG seed for the run.
    pub seed: u64,
    /// Engine-config override (Table 6 ladder); `None` picks the natural
    /// config for the system (WOSS → full integration, others → plain).
    pub engine: Option<EngineConfig>,
    /// Scheduler override; `None` picks the natural scheduler.
    pub scheduler: Option<SchedKind>,
}

/// Scheduler selection for overrides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    /// Baseline least-loaded, round-robin tie-break.
    LeastLoaded,
    /// WOSS integration: locality-first with a queue budget.
    LocationAware,
    /// Pays for location queries but schedules like the baseline
    /// (Table 6's "get location" rung).
    ProbeLocation,
    /// Follow data unconditionally (node-local file system runs, where
    /// a file is only readable where it was written).
    FollowData,
}

impl RunSpec {
    /// Natural spec for a system on the 20-node cluster.
    pub fn cluster(system: SystemKind, seed: u64) -> Self {
        RunSpec {
            system,
            nodes: 20,
            backend: Backend::Nfs,
            calib: Calib::cluster(),
            seed,
            engine: None,
            scheduler: None,
        }
    }

    /// Natural spec for a system on a BG/P allocation of `nodes`.
    pub fn bgp(system: SystemKind, nodes: usize, seed: u64) -> Self {
        RunSpec {
            system,
            nodes,
            backend: Backend::Gpfs,
            calib: Calib::bgp(),
            seed,
            engine: None,
            scheduler: None,
        }
    }
}

fn make_scheduler(kind: SchedKind) -> Box<dyn Scheduler> {
    match kind {
        SchedKind::LeastLoaded => Box::new(LeastLoaded::new()),
        SchedKind::LocationAware => Box::new(LocationAware::new()),
        SchedKind::ProbeLocation => Box::new(ProbeLocation::new()),
        SchedKind::FollowData => {
            let mut s = LocationAware::new();
            s.min_gravity_bytes = 0.0;
            s.max_queue = 10_000;
            Box::new(s)
        }
    }
}

/// Execute one workflow run under `spec`.
pub fn execute(spec: &RunSpec, workflow: &Workflow) -> RunResult {
    let mut cluster = Cluster::new(spec.nodes, spec.system.disk_kind(), &spec.calib);

    let mut backend: Box<dyn StorageModel> = match spec.backend {
        Backend::Nfs => Box::new(NfsServer::new(&spec.calib)),
        Backend::Gpfs => Box::new(Gpfs::new(&spec.calib)),
    };

    let mut inter: Box<dyn StorageModel> = match spec.system {
        SystemKind::Nfs => Box::new(NfsServer::new(&spec.calib)),
        SystemKind::GpfsOnly => Box::new(Gpfs::new(&spec.calib)),
        SystemKind::LocalRam => Box::new(LocalFs::new()),
        s => Box::new(standard_deployment(
            &cluster,
            s.is_woss(),
            s.disk_kind() == DiskKind::RamDisk,
            spec.seed ^ 0x5707_AA5E,
        )),
    };

    let engine_cfg = spec.engine.clone().unwrap_or_else(|| {
        if spec.system.is_woss() {
            EngineConfig::woss(spec.seed)
        } else if spec.system == SystemKind::LocalRam {
            // The shell script knows where it ran; it follows files
            // without paying remote location queries.
            EngineConfig {
                tag_outputs: false,
                useless_tags: false,
                query_location: true,
                charge_fork: false,
                fork_only: false,
                jitter: 0.03,
                seed: spec.seed,
                stage_in_barrier: true,
                tag_lifetime: false,
            }
        } else {
            EngineConfig::plain(spec.seed)
        }
    });

    let sched_kind = spec.scheduler.unwrap_or(match spec.system {
        s if s.is_woss() => SchedKind::LocationAware,
        SystemKind::LocalRam => SchedKind::FollowData,
        _ => SchedKind::LeastLoaded,
    });
    let mut scheduler = make_scheduler(sched_kind);

    run_workflow(
        &mut cluster,
        inter.as_mut(),
        backend.as_mut(),
        scheduler.as_mut(),
        engine_cfg,
        workflow,
    )
    .expect("workflow run failed")
}

/// Repeat a run with derived seeds; returns per-run makespans and the
/// last run's full result (for breakdown rows).
pub fn repeat<F: Fn(u64) -> Workflow>(
    spec: &RunSpec,
    runs: usize,
    build: F,
) -> (Summary, RunResult) {
    assert!(runs >= 1);
    let mut summary = Summary::new();
    let mut last = None;
    for r in 0..runs {
        let mut s = spec.clone();
        s.seed = spec
            .seed
            .wrapping_add((r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
        if let Some(e) = &mut s.engine {
            e.seed = s.seed;
        }
        let wf = build(s.seed);
        let result = execute(&s, &wf);
        summary.add(result.makespan);
        last = Some(result);
    }
    (summary, last.expect("at least one run"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn pipeline_system_ordering() {
        // The paper's headline: WOSS ≈ local ≫ DSS ≫ NFS on pipeline.
        let runs = 3;
        let (nfs, _) = repeat(&RunSpec::cluster(SystemKind::Nfs, 1), runs, |_| {
            workloads::pipeline(19, 1.0, false)
        });
        let (dss, _) = repeat(&RunSpec::cluster(SystemKind::DssRam, 1), runs, |_| {
            workloads::pipeline(19, 1.0, false)
        });
        let (woss, _) = repeat(&RunSpec::cluster(SystemKind::WossRam, 1), runs, |_| {
            workloads::pipeline(19, 1.0, true)
        });
        assert!(
            woss.mean() < dss.mean() && dss.mean() < nfs.mean(),
            "WOSS {:.1} < DSS {:.1} < NFS {:.1}",
            woss.mean(),
            dss.mean(),
            nfs.mean()
        );
        assert!(
            nfs.mean() / woss.mean() > 3.0,
            "NFS/WOSS ratio {:.1} too small",
            nfs.mean() / woss.mean()
        );
    }

    #[test]
    fn repeat_is_deterministic() {
        let spec = RunSpec::cluster(SystemKind::WossRam, 7);
        let (a, _) = repeat(&spec, 2, |_| workloads::reduce(8, 1.0, true));
        let (b, _) = repeat(&spec, 2, |_| workloads::reduce(8, 1.0, true));
        assert_eq!(a.samples(), b.samples());
    }
}
