//! Per-figure/table experiment drivers.
//!
//! Each driver regenerates one artifact from the paper's evaluation
//! (§4): the same configurations, the same sweep axis, the same reported
//! rows — on the simulated testbed. `woss experiment <id>` prints the
//! table; `woss experiment all --json out.json` additionally dumps
//! machine-readable results that EXPERIMENTS.md is built from.

use crate::bench::{execute, repeat, RunSpec, SchedKind, SystemKind};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workflow::engine::EngineConfig;
use crate::workloads::{self, Blast, ModFtDock, Montage};

/// One regenerated figure/table.
pub struct Report {
    /// Experiment id ("fig5", "table6", ...).
    pub id: &'static str,
    /// Human title.
    pub title: &'static str,
    /// Rendered rows.
    pub table: Table,
    /// Machine-readable record.
    pub json: Json,
    /// Shape expectations from the paper, for the reader.
    pub expectation: &'static str,
}

/// All known experiment ids, in paper order.
pub fn ids() -> Vec<&'static str> {
    vec![
        "fig5", "fig6", "fig7", "fig8", "fig10", "fig11", "table4", "fig14", "table6",
        "table6_shards", "live_throughput", "live_cache", "live_recovery", "scale", "ablation",
    ]
}

/// The live-engine experiment ids — the `woss experiment live` group
/// whose JSON output becomes the tracked `BENCH_live.json`.
pub fn live_ids() -> Vec<&'static str> {
    vec!["live_throughput", "live_cache", "live_recovery"]
}

/// Run one experiment by id.
pub fn run(id: &str, runs: usize, seed: u64) -> Option<Report> {
    match id {
        "fig5" => Some(fig5(runs, seed)),
        "fig6" => Some(fig6(runs, seed)),
        "fig7" => Some(fig7(runs, seed)),
        "fig8" => Some(fig8(runs, seed)),
        "fig10" => Some(fig10(runs, seed)),
        "fig11" => Some(fig11(runs.min(3), seed)),
        "table4" => Some(table4(runs, seed)),
        "fig14" => Some(fig14(runs, seed)),
        "table6" => Some(table6(runs, seed)),
        "table6_shards" => Some(table6_shards(runs, seed)),
        "live_throughput" => Some(live_throughput(runs, seed)),
        "live_cache" => Some(live_cache(runs, seed)),
        "live_recovery" => Some(live_recovery(runs, seed)),
        "scale" => Some(scale(runs, seed)),
        "ablation" => Some(ablation(runs, seed)),
        _ => None,
    }
}

/// Run every experiment.
pub fn run_all(runs: usize, seed: u64) -> Vec<Report> {
    ids().iter().map(|id| run(id, runs, seed).unwrap()).collect()
}

const SYNTH_SYSTEMS: [SystemKind; 5] = [
    SystemKind::Nfs,
    SystemKind::DssDisk,
    SystemKind::DssRam,
    SystemKind::WossDisk,
    SystemKind::WossRam,
];

fn hints_for(sys: SystemKind) -> bool {
    matches!(
        sys,
        SystemKind::WossDisk | SystemKind::WossRam | SystemKind::LocalRam
    )
}

fn mean_wf<F: Fn(u64) -> crate::workflow::Workflow>(
    sys: SystemKind,
    seed: u64,
    runs: usize,
    build: F,
) -> f64 {
    let mut sum = 0.0;
    for r in 0..runs {
        let mut spec = RunSpec::cluster(sys, seed);
        spec.seed = seed.wrapping_add(r as u64 * 7919);
        let wf = build(spec.seed);
        sum += execute(&spec, &wf).workflow_span();
    }
    sum / runs as f64
}

/// Figure 5: pipeline synthetic benchmark (workflow time, staging
/// reported separately).
fn fig5(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 5 — pipeline benchmark, 19 pipelines (avg over runs)")
        .header(["system", "workflow (s)", "± σ", "stage-in (s)", "total (s)"]);
    let mut json = Json::obj([("id", "fig5".into()), ("runs", runs.into())]);
    let mut rows = Vec::new();
    let mut systems: Vec<SystemKind> = SYNTH_SYSTEMS.to_vec();
    systems.push(SystemKind::LocalRam);
    for sys in systems {
        let mut wf_summary = crate::util::Summary::new();
        let mut stage_in = 0.0;
        let mut total = 0.0;
        for r in 0..runs {
            let wf = workloads::pipeline(19, 1.0, hints_for(sys));
            let mut s = RunSpec::cluster(sys, seed);
            s.seed = seed.wrapping_add(r as u64 * 7919);
            let result = execute(&s, &wf);
            wf_summary.add(result.workflow_span());
            stage_in = result.stage_end("stageIn");
            total = result.makespan;
        }
        table.row([
            sys.label().to_string(),
            format!("{:.1}", wf_summary.mean()),
            format!("{:.2}", wf_summary.stddev()),
            format!("{stage_in:.1}"),
            format!("{total:.1}"),
        ]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("workflow_s", wf_summary.mean().into()),
            ("stddev", wf_summary.stddev().into()),
            ("total_s", total.into()),
        ]));
    }
    json.set("rows", Json::Arr(rows));
    Report {
        id: "fig5",
        title: "Pipeline synthetic benchmark",
        table,
        json,
        expectation: "paper: WOSS ≈ local, ~10x vs NFS, ~2x vs DSS",
    }
}

/// Figure 6: broadcast benchmark vs replication factor.
fn fig6(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 6 — broadcast benchmark (19 consumers)")
        .header(["system", "replication", "workflow (s)"]);
    let mut rows = Vec::new();
    // Baselines.
    for sys in [SystemKind::Nfs, SystemKind::DssRam] {
        let m = mean_wf(sys, seed, runs, |_| workloads::broadcast(19, 1, 1.0, false));
        table.row([sys.label().to_string(), "-".to_string(), format!("{m:.1}")]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("replication", Json::Null),
            ("workflow_s", m.into()),
        ]));
    }
    // WOSS sweep.
    for rep in [1u32, 2, 4, 8, 12, 16] {
        let m = mean_wf(SystemKind::WossRam, seed, runs, |_| {
            workloads::broadcast(19, rep, 1.0, true)
        });
        table.row(["WOSS-RAM".to_string(), rep.to_string(), format!("{m:.1}")]);
        rows.push(Json::obj([
            ("system", "WOSS-RAM".into()),
            ("replication", (rep as u64).into()),
            ("workflow_s", m.into()),
        ]));
    }
    Report {
        id: "fig6",
        title: "Broadcast benchmark vs replication factor",
        table,
        json: Json::obj([
            ("id", "fig6".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: optimum around 8 replicas; over-replication costs more than it gains",
    }
}

/// Figure 7: reduce benchmark.
fn fig7(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 7 — reduce benchmark (19 producers → 1 reducer)")
        .header(["system", "workflow (s)"]);
    let mut rows = Vec::new();
    for sys in SYNTH_SYSTEMS {
        let m = mean_wf(sys, seed, runs, |_| workloads::reduce(19, 1.0, hints_for(sys)));
        table.row([sys.label().to_string(), format!("{m:.1}")]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("workflow_s", m.into()),
        ]));
    }
    Report {
        id: "fig7",
        title: "Reduce benchmark",
        table,
        json: Json::obj([
            ("id", "fig7".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: WOSS ~4x vs NFS; DSS shows a smaller gain (our NIC-physics model caps the factor; ordering reproduces — see EXPERIMENTS.md)",
    }
}

/// Figure 8: scatter benchmark (stage 2 only, per the paper).
fn fig8(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 8 — scatter benchmark, stage 2 (19 region readers)")
        .header(["system", "stage-2 (s)"]);
    let mut rows = Vec::new();
    for sys in SYNTH_SYSTEMS {
        let mut sum = 0.0;
        for r in 0..runs {
            let mut spec = RunSpec::cluster(sys, seed);
            spec.seed = seed.wrapping_add(r as u64 * 7919);
            let wf = workloads::scatter(19, 1.0, hints_for(sys));
            let result = execute(&spec, &wf);
            sum += result.stage_end("readRegion") - result.stage_start("readRegion");
        }
        let m = sum / runs as f64;
        table.row([sys.label().to_string(), format!("{m:.2}")]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("stage2_s", m.into()),
        ]));
    }
    Report {
        id: "fig8",
        title: "Scatter benchmark (stage 2)",
        table,
        json: Json::obj([
            ("id", "fig8".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: ~10.4x vs NFS, ~2x vs DSS",
    }
}

/// Figure 10: modFTDock on the cluster (Swift runtime).
fn fig10(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 10 — modFTDock, 9 streams, 18 nodes (Swift)")
        .header(["system", "total (s)", "± σ"]);
    let mut rows = Vec::new();
    for sys in [SystemKind::Nfs, SystemKind::DssRam, SystemKind::WossRam] {
        let mut spec = RunSpec::cluster(sys, seed);
        // Swift personality on the cluster: per-tag-op task launch.
        spec.calib.swift_tag_task_ms = 20.0;
        let dock = ModFtDock {
            hints: hints_for(sys),
            ..Default::default()
        };
        let (sum, _) = repeat(&spec, runs, |_| dock.build());
        table.row([
            sys.label().to_string(),
            format!("{:.1}", sum.mean()),
            format!("{:.2}", sum.stddev()),
        ]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("total_s", sum.mean().into()),
        ]));
    }
    Report {
        id: "fig10",
        title: "modFTDock on the cluster",
        table,
        json: Json::obj([
            ("id", "fig10".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: WOSS ~20% faster than DSS, >2x vs NFS",
    }
}

/// Figure 11: modFTDock scaling on BG/P over GPFS.
fn fig11(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 11 — modFTDock on BG/P (workload ∝ nodes)")
        .header(["nodes", "GPFS (s)", "DSS (s)", "WOSS+Swift (s)"]);
    let mut rows = Vec::new();
    for nodes in [64usize, 128, 256, 512] {
        let mut row = vec![nodes.to_string()];
        let mut jrow = Json::obj([("nodes", nodes.into())]);
        for sys in [SystemKind::GpfsOnly, SystemKind::DssRam, SystemKind::WossRam] {
            let spec = RunSpec::bgp(sys, nodes, seed);
            // BG/P calib carries swift_tag_task_ms = 50 ms; it only
            // bites for WOSS (the only config issuing tag ops).
            let dock = ModFtDock::bgp(nodes, hints_for(sys));
            let (sum, _) = repeat(&spec, runs, |_| dock.build());
            row.push(format!("{:.0}", sum.mean()));
            let key = match sys {
                SystemKind::GpfsOnly => "gpfs_s",
                SystemKind::DssRam => "dss_s",
                _ => "woss_s",
            };
            jrow.set(key, sum.mean().into());
        }
        table.row(row);
        rows.push(jrow);
    }
    Report {
        id: "fig11",
        title: "modFTDock scaling on BG/P",
        table,
        json: Json::obj([
            ("id", "fig11".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: DSS 20-40% faster than GPFS; WOSS loses its gains to Swift's per-tag-op task-launch overhead",
    }
}

/// Table 4: BLAST runtime breakdown vs DB replication level.
fn table4(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Table 4 — BLAST execution breakdown (seconds)")
        .header(["row", "NFS", "DSS", "WOSS r2", "WOSS r4", "WOSS r8", "WOSS r16"]);
    let mut configs: Vec<(String, SystemKind, Option<u32>)> = vec![
        ("NFS".into(), SystemKind::Nfs, None),
        ("DSS".into(), SystemKind::DssRam, None),
    ];
    for rep in [2u32, 4, 8, 16] {
        configs.push((format!("WOSS r{rep}"), SystemKind::WossRam, Some(rep)));
    }

    // rows: stage-in, 90% tasks, all tasks, stage-out, total
    let mut cells: Vec<[f64; 5]> = Vec::new();
    let mut jrows = Vec::new();
    for (label, sys, rep) in &configs {
        let mut acc = [0.0f64; 5];
        for r in 0..runs {
            let mut spec = RunSpec::cluster(*sys, seed);
            spec.seed = seed.wrapping_add(r as u64 * 104729);
            let blast = Blast {
                db_replication: *rep,
                ..Default::default()
            };
            let result = execute(&spec, &blast.build());
            let stage_in = result.stage_end("stageIn");
            let p90 = result.finish_percentile(90.0, |t| t.stage == "blast");
            let all = result.stage_end("blast");
            let total = result.makespan;
            let stage_out = total - all;
            for (i, v) in [stage_in, p90, all, stage_out, total].iter().enumerate() {
                acc[i] += v / runs as f64;
            }
        }
        cells.push(acc);
        jrows.push(Json::obj([
            ("config", label.as_str().into()),
            ("stage_in_s", acc[0].into()),
            ("p90_s", acc[1].into()),
            ("all_tasks_s", acc[2].into()),
            ("stage_out_s", acc[3].into()),
            ("total_s", acc[4].into()),
        ]));
    }
    let row_names = [
        "Stage-in",
        "90% workflow tasks",
        "All tasks finished",
        "Stage-out",
        "Total",
    ];
    for (i, name) in row_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for acc in &cells {
            row.push(format!("{:.0}", acc[i]));
        }
        table.row(row);
    }
    Report {
        id: "table4",
        title: "BLAST breakdown vs replication level",
        table,
        json: Json::obj([
            ("id", "table4".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(jrows)),
        ]),
        expectation: "paper: stage-in grows with replicas, task time shrinks; best total before 16; WOSS up to ~40% vs NFS, ~15% vs DSS",
    }
}

/// Figure 14: Montage end-to-end.
fn fig14(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Figure 14 — Montage workflow execution time (pyFlow)")
        .header(["system", "total (s)", "± σ"]);
    let mut rows = Vec::new();
    for sys in [SystemKind::Nfs, SystemKind::DssDisk, SystemKind::WossDisk] {
        let spec = RunSpec::cluster(sys, seed);
        let m = Montage {
            hints: hints_for(sys),
            ..Default::default()
        };
        let (sum, _) = repeat(&spec, runs, |_| m.build());
        table.row([
            sys.label().to_string(),
            format!("{:.1}", sum.mean()),
            format!("{:.2}", sum.stddev()),
        ]);
        rows.push(Json::obj([
            ("system", sys.label().into()),
            ("total_s", sum.mean().into()),
        ]));
    }
    Report {
        id: "fig14",
        title: "Montage end-to-end",
        table,
        json: Json::obj([
            ("id", "fig14".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: WOSS ~30% faster than NFS and ~10% faster than DSS on disk",
    }
}

/// Table 6: the overhead/gain ladder on Montage.
fn table6(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Table 6 — WOSS microbenchmark (Montage)")
        .header(["experiment setup", "total (s)"]);
    let base = EngineConfig::plain(seed);
    let ladder: Vec<(&str, SystemKind, EngineConfig, Option<SchedKind>, bool)> = vec![
        ("DSS", SystemKind::DssDisk, base.clone(), None, false),
        (
            "DSS + fork",
            SystemKind::DssDisk,
            EngineConfig {
                tag_outputs: true,
                useless_tags: true,
                charge_fork: true,
                fork_only: true,
                ..base.clone()
            },
            None,
            true,
        ),
        (
            "DSS + fork + tagging",
            SystemKind::DssDisk,
            EngineConfig {
                tag_outputs: true,
                useless_tags: true,
                charge_fork: true,
                ..base.clone()
            },
            None,
            true,
        ),
        (
            "DSS + fork + tagging + get location",
            SystemKind::DssDisk,
            EngineConfig {
                tag_outputs: true,
                useless_tags: true,
                charge_fork: true,
                query_location: true,
                ..base.clone()
            },
            Some(SchedKind::ProbeLocation),
            true,
        ),
        (
            "DSS + fork + tagging + get location + loc-aware sched (useless tags)",
            SystemKind::DssDisk,
            EngineConfig {
                tag_outputs: true,
                useless_tags: true,
                charge_fork: true,
                query_location: true,
                ..base.clone()
            },
            Some(SchedKind::LocationAware),
            true,
        ),
        (
            "WOSS (all of the above with useful tags)",
            SystemKind::WossDisk,
            EngineConfig::woss(seed),
            None,
            true,
        ),
    ];
    let mut rows = Vec::new();
    for (label, sys, cfg, sched, tagged_workload) in ladder {
        let mut sum = 0.0;
        for r in 0..runs {
            let mut spec = RunSpec::cluster(sys, seed);
            spec.seed = seed.wrapping_add(r as u64 * 31);
            spec.engine = Some(EngineConfig {
                seed: spec.seed,
                ..cfg.clone()
            });
            spec.scheduler = sched;
            let m = Montage {
                hints: tagged_workload,
                ..Default::default()
            };
            sum += execute(&spec, &m.build()).makespan;
        }
        let mean = sum / runs as f64;
        table.row([label.to_string(), format!("{mean:.1}")]);
        rows.push(Json::obj([
            ("setup", label.into()),
            ("total_s", mean.into()),
        ]));
    }
    Report {
        id: "table6",
        title: "Overhead/gain ladder",
        table,
        json: Json::obj([
            ("id", "table6".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: each rung adds overhead (up to ~7%, tagging dominant via the serialized set-attr queue); WOSS ends below plain DSS",
    }
}

/// Table 6 variant: the serialized `set-attribute` bottleneck vs the
/// sharded/batched metadata path.
///
/// Part 1 replays Table 6's pressure point directly: a storm of tagging
/// RPCs from every client at t=0 against the manager, sweeping the shard
/// count. The 1-shard serialized row *is* the Table 6 configuration
/// (`manager_shards = 1, manager_setattr_serialized = true`); each
/// doubling of the shard count should roughly double setattr throughput.
/// Part 2 holds shards at 1 and sweeps the batch size of
/// [`crate::storage::Manager::set_attrs_bulk`], showing the per-RPC cost
/// amortizing within a single queue.
fn table6_shards(runs: usize, seed: u64) -> Report {
    use crate::dispatch::Registry;
    use crate::sim::{Calib, Cluster, DiskKind, Metrics, SimTime};
    use crate::storage::{Manager, NodeId, NodeState};

    const OPS: usize = 128;
    const CLIENTS: usize = 19;

    let mut table = Table::new("Table 6 variant — setattr throughput vs shards and batch size")
        .header(["knob", "value", "storm completion (s)", "setattr ops/s"]);
    let mut rows = Vec::new();

    let storm = |shards: usize, batch: usize, seed: u64| -> (f64, u64) {
        let calib = Calib {
            manager_shards: shards,
            setattr_batch: batch,
            // Table 6's acknowledged behaviour: serialized per-shard queue.
            manager_setattr_serialized: true,
            ..Calib::default()
        };
        let mut cluster = Cluster::new(20, DiskKind::RamDisk, &calib);
        let nodes: Vec<NodeState> = (1..20)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: u64::MAX / 2,
                used: 0,
            })
            .collect();
        let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
        let mut metrics = Metrics::new();
        let mut last = SimTime::ZERO;
        // Every client tags its own output files at t=0 — the many-task
        // tagging storm the serialized queue chokes on. Each file carries
        // `batch` attributes, issued through the batched API.
        let pairs: Vec<(String, String)> = (0..batch)
            .map(|i| (format!("k{i}"), format!("v{seed}")))
            .collect();
        for op in 0..OPS {
            let client = NodeId(1 + (op % CLIENTS));
            let path = format!("/wf/out{op}");
            let done = mgr
                .set_attrs_bulk(&mut cluster, &mut metrics, client, &path, &pairs, SimTime::ZERO)
                .expect("setattr storm");
            last = last.max(done);
        }
        let secs = last.as_secs_f64();
        (secs, metrics.setattr_ops)
    };

    // Part 1: shard sweep at batch=1 (one attribute per RPC, the
    // prototype's behaviour). The storm runs straight against the
    // manager with no jitter, so one run per configuration is exact —
    // `runs` repetitions would reproduce the same numbers.
    for shards in [1usize, 2, 4, 8] {
        let (secs, ops) = storm(shards, 1, seed);
        let thr = ops as f64 / secs.max(1e-12);
        table.row([
            "manager_shards".to_string(),
            shards.to_string(),
            format!("{secs:.4}"),
            format!("{thr:.0}"),
        ]);
        rows.push(Json::obj([
            ("knob", "manager_shards".into()),
            ("value", shards.into()),
            ("storm_s", secs.into()),
            ("setattr_per_s", thr.into()),
        ]));
    }

    // Part 2: batch sweep at the Table 6 shard count (1, serialized).
    for batch in [1usize, 4, 16] {
        let (secs, ops) = storm(1, batch, seed);
        let thr = ops as f64 / secs.max(1e-12);
        table.row([
            "setattr_batch".to_string(),
            batch.to_string(),
            format!("{secs:.4}"),
            format!("{thr:.0}"),
        ]);
        rows.push(Json::obj([
            ("knob", "setattr_batch".into()),
            ("value", batch.into()),
            ("storm_s", secs.into()),
            ("setattr_per_s", thr.into()),
        ]));
    }

    Report {
        id: "table6_shards",
        title: "Setattr throughput vs manager shards / batch size",
        table,
        json: Json::obj([
            ("id", "table6_shards".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "shards=1 serialized is the Table 6 bottleneck; throughput scales ~linearly with shard count, and batching amortizes the per-RPC cost on a single queue",
    }
}

/// Live-store concurrency sweep: tagged-write and read throughput vs
/// lock-stripe count × thread count, on all three chunk backends (the
/// in-memory store, the file-per-chunk spill tier, and the packed
/// segment log), plus mean tagged-write latency under optimistic vs
/// pessimistic replication semantics. Unlike the other experiments
/// this one measures *wall-clock* behaviour of the live (real-bytes,
/// real-threads) store, so absolute numbers vary by machine; the
/// shapes — reads scaling with reader threads, optimistic returning
/// before full replication, the persistent backends paying an I/O cost
/// the memory backend does not — are the reproducible claim.
fn live_throughput(_runs: usize, seed: u64) -> Report {
    use crate::hints::TagSet;
    use crate::live::{BackendKind, LiveStore, LiveTuning};
    use crate::storage::types::NodeId;
    use std::time::Instant;

    const NODES: usize = 8;
    const REPL_WORKERS: usize = 2;
    const FILES: usize = 12;
    const FILE_BYTES: usize = 512 * 1024;
    const READS_PER_THREAD: usize = 48;
    const LATENCY_WRITES: usize = 24;

    let mut table =
        Table::new("Live store — concurrent throughput vs backend, lock stripes, threads")
            .header(["backend", "stripes", "threads", "tagged-write MB/s", "read MB/s"]);
    let mut rows = Vec::new();
    let data: Vec<u8> = (0..FILE_BYTES)
        .map(|i| ((i as u64).wrapping_mul(31).wrapping_add(seed)) as u8)
        .collect();

    for backend in [BackendKind::Memory, BackendKind::Disk, BackendKind::Seg] {
        for stripes in [1usize, 4, 8] {
            for threads in [1usize, 2, 4] {
                let store = LiveStore::woss_with(
                    NODES,
                    LiveTuning {
                        stripes,
                        repl_workers: REPL_WORKERS,
                        backend,
                        ..LiveTuning::default()
                    },
                );
                // Per-row latency distributions: the reservoirs start
                // empty for every (backend, stripes, threads) cell,
                // so a row's percentile columns can never echo a
                // previous configuration's samples.
                store.reset_latency_samples();
                // Tagged-write phase: every write carries placement +
                // replication hints (the cross-layer hot path), each
                // writer thread creating its own files.
                let t0 = Instant::now();
                std::thread::scope(|scope| {
                    for t in 0..threads {
                        let store = &store;
                        let data = &data;
                        scope.spawn(move || {
                            let tags = TagSet::from_pairs([
                                ("DP", "scatter 1"),
                                ("Replication", "2"),
                                ("RepSmntc", "optimistic"),
                            ]);
                            for f in 0..FILES {
                                store
                                    .write_file(
                                        NodeId(t % NODES),
                                        &format!("/w{t}/f{f}"),
                                        data,
                                        &tags,
                                    )
                                    .expect("bench write");
                            }
                        });
                    }
                });
                let write_secs = t0.elapsed().as_secs_f64();
                store.flush_replication();

                // Read phase: reader threads sweep the files concurrently.
                let t1 = Instant::now();
                std::thread::scope(|scope| {
                    for r in 0..threads {
                        let store = &store;
                        scope.spawn(move || {
                            for i in 0..READS_PER_THREAD {
                                let t = (r + i) % threads;
                                let f = i % FILES;
                                let back = store
                                    .read_file(NodeId((r + 1) % NODES), &format!("/w{t}/f{f}"))
                                    .expect("bench read");
                                assert_eq!(back.len(), FILE_BYTES);
                            }
                        });
                    }
                });
                let read_secs = t1.elapsed().as_secs_f64();

                let mb = FILE_BYTES as f64 / (1024.0 * 1024.0);
                let write_mbps = threads as f64 * FILES as f64 * mb / write_secs.max(1e-9);
                let read_mbps = threads as f64 * READS_PER_THREAD as f64 * mb / read_secs.max(1e-9);
                // Per-op latency distributions (µs) — the percentile
                // fields `woss bench-check` gates on BENCH_live.json.
                let cs = store.cache_stats();
                table.row([
                    backend.label().to_string(),
                    stripes.to_string(),
                    threads.to_string(),
                    format!("{write_mbps:.0}"),
                    format!("{read_mbps:.0}"),
                ]);
                rows.push(Json::obj([
                    ("backend", backend.label().into()),
                    ("stripes", stripes.into()),
                    ("threads", threads.into()),
                    ("write_mbps", write_mbps.into()),
                    ("read_mbps", read_mbps.into()),
                    ("put_p50_us", cs.put_p50_us.into()),
                    ("put_p95_us", cs.put_p95_us.into()),
                    ("put_p99_us", cs.put_p99_us.into()),
                    ("get_p50_us", cs.get_p50_us.into()),
                    ("get_p95_us", cs.get_p95_us.into()),
                    ("get_p99_us", cs.get_p99_us.into()),
                    ("spill_p50_us", cs.spill_p50_us.into()),
                    ("spill_p95_us", cs.spill_p95_us.into()),
                    ("spill_p99_us", cs.spill_p99_us.into()),
                ]));
            }
        }
    }

    // Latency rows: mean tagged-write latency under both `RepSmntc`
    // semantics at Replication=4 — the optimistic write returns after
    // the primary copy, the pessimistic one after all four. The memory
    // backend keeps the comparison about replication semantics alone.
    let mut latency = Vec::new();
    for sem in ["optimistic", "pessimistic"] {
        let store = LiveStore::woss_with(
            NODES,
            LiveTuning {
                stripes: 4,
                repl_workers: REPL_WORKERS,
                backend: BackendKind::Memory,
                ..LiveTuning::default()
            },
        );
        let tags = TagSet::from_pairs([("Replication", "4"), ("RepSmntc", sem)]);
        let t0 = Instant::now();
        for f in 0..LATENCY_WRITES {
            store
                .write_file(NodeId(f % NODES), &format!("/lat/{f}"), &data, &tags)
                .expect("latency write");
        }
        let mean_us = t0.elapsed().as_secs_f64() * 1e6 / LATENCY_WRITES as f64;
        store.flush_replication();
        table.row([
            "mem".to_string(),
            "RepSmntc".to_string(),
            sem.to_string(),
            format!("{mean_us:.0} us/write"),
            String::new(),
        ]);
        latency.push(Json::obj([
            ("semantics", sem.into()),
            ("mean_write_us", mean_us.into()),
        ]));
    }

    Report {
        id: "live_throughput",
        title: "Live store concurrent throughput (backend × stripes × threads)",
        table,
        json: Json::obj([
            ("id", "live_throughput".into()),
            ("rows", Json::Arr(rows)),
            ("latency", Json::Arr(latency)),
        ]),
        expectation: "read throughput scales with reader threads (≥2x from 1→4 threads at 4 stripes on a ≥4-core box); the persistent backends trail the memory backend on both phases (file I/O), with seg ahead of disk on writes (one group-committed log append vs a file create + fsync per chunk); optimistic tagged writes return well below the pessimistic latency; stripes=1 reproduces the single-lock manager behaviour",
    }
}

/// Live cache-tier sweep: locality vs cache budget × eviction policy ×
/// chunk backend on a pipeline-shaped trace (a hot durable reference
/// set re-read every round while read-once scratch intermediates
/// stream through), plus a disk-penalty recovery measurement and the
/// prefetch and reclamation demonstrations. Single driver thread, so
/// every counter row is deterministic: the claim under test is the
/// policy shape, not wall-clock throughput (the disk-penalty rows also
/// report wall-clock, which varies by machine).
fn live_cache(_runs: usize, _seed: u64) -> Report {
    use crate::hints::TagSet;
    use crate::live::{BackendKind, CachePolicy, LiveStore, LiveTuning};
    use crate::storage::types::NodeId;
    use std::time::Instant;

    const NODES: usize = 4;
    const CHUNK: usize = 256 * 1024; // one LIVE_CHUNK per file
    const HOT: usize = 2; // durable reference files, re-read each round
    const SCRATCH_PER_ROUND: usize = 6; // read-once intermediates
    const ROUNDS: usize = 8;
    const TIGHT: u64 = 4 * CHUNK as u64; // < round working set
    const AMPLE: u64 = 16 * CHUNK as u64; // > round working set

    let data = vec![0xC5u8; CHUNK];
    let mut table = Table::new("Live store — hint-aware cache tier vs plain LRU, per backend")
        .header(["backend", "policy", "cache", "locality", "hits / evictions / peak KiB"]);
    let mut rows = Vec::new();

    for backend in [BackendKind::Memory, BackendKind::Disk, BackendKind::Seg] {
        for (policy, label) in [(CachePolicy::Lru, "lru"), (CachePolicy::HintAware, "hint")] {
            for budget in [TIGHT, AMPLE] {
                let store = LiveStore::woss_with(
                    NODES,
                    LiveTuning {
                        stripes: 4,
                        repl_workers: 1,
                        cache_bytes: Some(budget),
                        cache_policy: policy,
                        lifetime: false,
                        backend,
                        ..LiveTuning::default()
                    },
                );
                // Producer (node 0) lays everything out locally, so
                // every consumer (node 1) read is remote unless the
                // cache serves.
                let durable = TagSet::from_pairs([("DP", "local")]);
                let scratch = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
                for h in 0..HOT {
                    store
                        .write_file(NodeId(0), &format!("/hot{h}"), &data, &durable)
                        .expect("hot write");
                }
                let mut next_scratch = 0usize;
                for _round in 0..ROUNDS {
                    for h in 0..HOT {
                        store
                            .read_file(NodeId(1), &format!("/hot{h}"))
                            .expect("hot read");
                    }
                    for _ in 0..SCRATCH_PER_ROUND {
                        let path = format!("/s{next_scratch}");
                        next_scratch += 1;
                        store
                            .write_file(NodeId(0), &path, &data, &scratch)
                            .expect("scratch write");
                        store.read_file(NodeId(1), &path).expect("scratch read");
                    }
                }
                let stats = store.cache_stats();
                let local = store.local_reads.load(std::sync::atomic::Ordering::Relaxed);
                let remote = store.remote_reads.load(std::sync::atomic::Ordering::Relaxed);
                let locality = local as f64 / (local + remote).max(1) as f64;
                table.row([
                    backend.label().to_string(),
                    label.to_string(),
                    format!("{} KiB", budget / 1024),
                    format!("{:.0}%", locality * 100.0),
                    format!(
                        "{} / {} / {}",
                        stats.hits,
                        stats.evictions,
                        stats.peak_node_resident / 1024
                    ),
                ]);
                rows.push(Json::obj([
                    ("backend", backend.label().into()),
                    ("policy", label.into()),
                    ("cache_kb", (budget / 1024).into()),
                    ("budget", budget.into()),
                    ("locality", locality.into()),
                    ("hits", stats.hits.into()),
                    ("evictions", stats.evictions.into()),
                    ("peak_resident", stats.peak_node_resident.into()),
                ]));
            }
        }
    }

    // Disk-penalty recovery: the same hot set read over and over. On
    // the disk backend with the cache off every consumer read is a
    // file read; the hint-aware cache serves all but the first round
    // from memory, recovering most of the penalty. Counters (remote
    // chunk fetches, cache hits) are deterministic; the seconds column
    // is machine-dependent flavour.
    const PENALTY_FILES: usize = 4;
    const PENALTY_ROUNDS: usize = 6;
    let mut penalty = Vec::new();
    for (config, backend, cache) in [
        ("mem/no-cache", BackendKind::Memory, None),
        ("disk/no-cache", BackendKind::Disk, None),
        ("disk/hint-cache", BackendKind::Disk, Some(AMPLE)),
    ] {
        let store = LiveStore::woss_with(
            2,
            LiveTuning {
                stripes: 4,
                repl_workers: 1,
                cache_bytes: cache,
                cache_policy: CachePolicy::HintAware,
                lifetime: false,
                backend,
                ..LiveTuning::default()
            },
        );
        let durable = TagSet::from_pairs([("DP", "local")]);
        for f in 0..PENALTY_FILES {
            store
                .write_file(NodeId(0), &format!("/ref{f}"), &data, &durable)
                .expect("penalty write");
        }
        let t0 = Instant::now();
        for _ in 0..PENALTY_ROUNDS {
            for f in 0..PENALTY_FILES {
                store
                    .read_file(NodeId(1), &format!("/ref{f}"))
                    .expect("penalty read");
            }
        }
        let read_s = t0.elapsed().as_secs_f64();
        let remote = store.remote_reads.load(std::sync::atomic::Ordering::Relaxed);
        let hits = store.cache_stats().hits;
        table.row([
            config.to_string(),
            "penalty".to_string(),
            String::new(),
            format!("{remote} remote chunk fetches"),
            format!("{hits} hits, {read_s:.4}s reads"),
        ]);
        penalty.push(Json::obj([
            ("config", config.into()),
            ("backend", backend.label().into()),
            ("remote_reads", remote.into()),
            ("cache_hits", hits.into()),
            ("read_s", read_s.into()),
        ]));
    }

    // Prefetch: a Pattern=pipeline handoff promoted into the consumer
    // node's cache off-thread makes the first (and only) read of the
    // next stage fully node-local.
    let store = LiveStore::woss_with(
        NODES,
        LiveTuning {
            stripes: 4,
            repl_workers: 1,
            cache_bytes: Some(AMPLE),
            cache_policy: CachePolicy::HintAware,
            lifetime: false,
            backend: BackendKind::Memory,
            ..LiveTuning::default()
        },
    );
    let stage_out = vec![0x3Au8; 4 * CHUNK];
    store
        .write_file(
            NodeId(0),
            "/pipe",
            &stage_out,
            &TagSet::from_pairs([("DP", "local"), ("Pattern", "pipeline")]),
        )
        .expect("pipeline write");
    let queued = store.prefetch(NodeId(1), "/pipe").expect("prefetch");
    store.flush_replication(); // barrier: promotions landed
    store.read_file(NodeId(1), "/pipe").expect("pipeline read");
    let pf_local = store.local_reads.load(std::sync::atomic::Ordering::Relaxed);
    let pf_stats = store.cache_stats();
    table.row([
        "prefetch".to_string(),
        "pipeline".to_string(),
        format!("{pf_local}/4 chunks local"),
        format!("{} promoted", pf_stats.prefetched),
    ]);
    let prefetch_json = Json::obj([
        ("queued", queued.into()),
        ("prefetched", pf_stats.prefetched.into()),
        ("local_reads", pf_local.into()),
    ]);

    // Reclamation: scratch files with one declared consumer die after
    // their only read — working-set bytes return before the run ends.
    let store = LiveStore::woss_with(
        NODES,
        LiveTuning {
            stripes: 4,
            repl_workers: 1,
            cache_bytes: Some(TIGHT),
            cache_policy: CachePolicy::HintAware,
            lifetime: true,
            backend: BackendKind::Memory,
            ..LiveTuning::default()
        },
    );
    let dead_tags = TagSet::from_pairs([
        ("DP", "local"),
        ("Lifetime", "scratch"),
        ("Consumers", "1"),
    ]);
    for i in 0..6 {
        store
            .write_file(NodeId(0), &format!("/r{i}"), &data, &dead_tags)
            .expect("scratch write");
    }
    for i in 0..6 {
        store
            .read_file(NodeId(1), &format!("/r{i}"))
            .expect("declared read");
    }
    let rc_stats = store.cache_stats();
    table.row([
        "reclaim".to_string(),
        "Consumers=1".to_string(),
        format!("{} files reclaimed", rc_stats.files_reclaimed),
        format!("{} KiB returned", rc_stats.bytes_reclaimed / 1024),
    ]);
    let reclaim_json = Json::obj([
        ("files_reclaimed", rc_stats.files_reclaimed.into()),
        ("bytes_reclaimed", rc_stats.bytes_reclaimed.into()),
    ]);

    Report {
        id: "live_cache",
        title: "Live cache tier — backend × eviction policy × budget, disk-penalty recovery",
        table,
        json: Json::obj([
            ("id", "live_cache".into()),
            ("rows", Json::Arr(rows)),
            ("disk_penalty", Json::Arr(penalty)),
            ("prefetch", prefetch_json),
            ("reclaim", reclaim_json),
        ]),
        expectation: "at the tight budget hint-aware eviction keeps the durable hot set resident where plain LRU churns it (higher locality at equal cache size, on every backend); at the ample budget the policies converge; peak resident bytes never exceed the per-node budget; on the disk backend the hint-aware cache serves every post-warm-up hot read from memory (remote chunk fetches collapse from rounds×files to files), recovering most of the cache-off disk read penalty; prefetch makes the pipeline handoff fully node-local; every Consumers=1 scratch file is reclaimed",
    }
}

/// Crash-and-restart recovery measurement on the disk backend: write a
/// durable working set (replicated) plus scratch intermediates, kill
/// the store (drop with no clean shutdown — as far as the disk is
/// concerned, a `kill -9` after `flush_replication`), reopen the same
/// data dir and check every durable file back byte-identical; then
/// shut down cleanly and reopen again through the snapshot path. The
/// reproducible claim is correctness (recovered = written, scratch
/// never resurrects); the reopen wall-clock rows contextualize the
/// salvage-vs-snapshot cost on this machine.
fn live_recovery(_runs: usize, seed: u64) -> Report {
    use crate::dispatch::Registry;
    use crate::hints::TagSet;
    use crate::live::{BackendKind, LiveStore, LiveTuning};
    use crate::storage::types::NodeId;
    use std::time::Instant;

    const NODES: usize = 4;
    const DURABLE: usize = 10;
    const SCRATCH: usize = 4;
    const FILE_BYTES: usize = 384 * 1024;

    let dir = std::env::var_os("WOSS_DATA_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(std::env::temp_dir)
        .join(format!("woss-live-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut table = Table::new("Live store — crash / clean-restart recovery (disk backend)")
        .header(["restart", "durable files", "byte-identical", "scratch back", "reopen ms"]);
    let mut rows = Vec::new();

    let tuning = || LiveTuning {
        backend: BackendKind::Disk,
        data_dir: Some(dir.clone()),
        ..LiveTuning::default()
    };
    let mut contents: Vec<(String, Vec<u8>)> = Vec::new();
    {
        let store = LiveStore::with_tuning(Registry::woss(), NODES, u64::MAX / 2, tuning());
        for f in 0..DURABLE {
            let data: Vec<u8> = (0..FILE_BYTES)
                .map(|i| ((i as u64).wrapping_mul(31).wrapping_add(seed.wrapping_add(f as u64))) as u8)
                .collect();
            let path = format!("/durable/{f}");
            let tags = TagSet::from_pairs([("Replication", "2")]);
            store
                .write_file(NodeId(f % NODES), &path, &data, &tags)
                .expect("recovery bench write");
            contents.push((path, data));
        }
        for f in 0..SCRATCH {
            let tags = TagSet::from_pairs([("Lifetime", "scratch")]);
            store
                .write_file(
                    NodeId(f % NODES),
                    &format!("/scratch/{f}"),
                    &vec![7u8; 64 * 1024],
                    &tags,
                )
                .expect("recovery bench scratch write");
        }
        store.flush_replication();
        // Dropped without shutdown(): the crash leg.
    }

    let mut measure = |label: &str| {
        let t0 = Instant::now();
        let store = LiveStore::reopen(Registry::woss(), &dir).expect("reopen recovery dir");
        let reopen_ms = t0.elapsed().as_secs_f64() * 1e3;
        let recovery = store.recovery_report().cloned().unwrap_or_default();
        let identical = contents
            .iter()
            .filter(|(path, data)| {
                store.read_file(NodeId(0), path).ok().as_deref() == Some(data.as_slice())
            })
            .count();
        let scratch_back = (0..SCRATCH)
            .filter(|f| store.file_size(&format!("/scratch/{f}")).is_some())
            .count();
        table.row([
            label.to_string(),
            format!("{}/{DURABLE}", recovery.files_recovered),
            identical.to_string(),
            scratch_back.to_string(),
            format!("{reopen_ms:.1}"),
        ]);
        rows.push(Json::obj([
            ("restart", label.into()),
            ("files_recovered", (recovery.files_recovered as u64).into()),
            ("byte_identical", (identical as u64).into()),
            ("scratch_resurrected", (scratch_back as u64).into()),
            ("clean", recovery.clean.into()),
            ("reopen_ms", reopen_ms.into()),
        ]));
        store.shutdown(); // next leg (if any) takes the snapshot path
    };
    measure("crash (journal salvage)");
    measure("clean (snapshot)");
    let _ = std::fs::remove_dir_all(&dir);

    Report {
        id: "live_recovery",
        title: "Live store crash consistency (disk backend restart)",
        table,
        json: Json::obj([("id", "live_recovery".into()), ("rows", Json::Arr(rows))]),
        expectation: "both restart legs recover all durable files byte-identical (10/10); no scratch file resurrects; the clean leg reports the snapshot path (clean=1) — durable data survives process death exactly as Lifetime=durable promises",
    }
}

/// §4.1 data-size sweep: 10x up and 1000x down.
fn scale(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Scale sweep — pipeline benchmark at 10x and 1/1000x data")
        .header(["scale", "system", "workflow (s)", "WOSS/DSS speedup"]);
    let mut rows = Vec::new();
    for scale in [10.0, 1.0, 0.001] {
        let mut vals = Vec::new();
        // Disk-backed variants: the 10x workload does not fit the 4 GB
        // RAM-disk nodes (it would not on the paper's testbed either).
        for sys in [SystemKind::Nfs, SystemKind::DssDisk, SystemKind::WossDisk] {
            let m = mean_wf(sys, seed, runs, |_| {
                workloads::pipeline(19, scale, hints_for(sys))
            });
            vals.push((sys, m));
        }
        let dss_m = vals
            .iter()
            .find(|(s, _)| *s == SystemKind::DssDisk)
            .map(|(_, m)| *m)
            .unwrap();
        let woss_m = vals
            .iter()
            .find(|(s, _)| *s == SystemKind::WossDisk)
            .map(|(_, m)| *m)
            .unwrap();
        for (sys, m) in &vals {
            let speedup = if *sys == SystemKind::WossDisk && woss_m > 0.0 {
                format!("{:.2}x", dss_m / woss_m)
            } else {
                String::new()
            };
            table.row([
                format!("{scale}"),
                sys.label().to_string(),
                format!("{m:.3}"),
                speedup,
            ]);
            rows.push(Json::obj([
                ("scale", scale.into()),
                ("system", sys.label().into()),
                ("workflow_s", (*m).into()),
            ]));
        }
    }
    Report {
        id: "scale",
        title: "Data-size sweep",
        table,
        json: Json::obj([
            ("id", "scale".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "paper: 10x keeps the trends; 1/1000x shows <10% differences and DSS can edge out WOSS (tag overhead unamortized)",
    }
}

/// Ablations over DESIGN.md's called-out design choices: the default
/// stripe width (MosaStore-style narrow striping) and the scheduler's
/// minimum-gravity threshold.
fn ablation(runs: usize, seed: u64) -> Report {
    let mut table = Table::new("Ablation — design-choice sweeps")
        .header(["knob", "value", "workload", "system", "time (s)"]);
    let mut rows = Vec::new();

    // Stripe width: single-node files hot-spot broadcasts; very wide
    // striping erases the baseline's sequential runs.
    for width in [1usize, 2, 4, 8, 18] {
        for (workload, label) in [(0usize, "pipeline(wf)"), (1, "broadcast(wf)")] {
            let mut sum = 0.0;
            for r in 0..runs {
                let mut spec = RunSpec::cluster(SystemKind::DssRam, seed);
                spec.seed = seed.wrapping_add(r as u64 * 6151);
                spec.calib.default_stripe_width = width;
                let wf = if workload == 0 {
                    workloads::pipeline(19, 1.0, false)
                } else {
                    workloads::broadcast(19, 1, 1.0, false)
                };
                sum += execute(&spec, &wf).workflow_span();
            }
            let m = sum / runs as f64;
            table.row([
                "stripe_width".to_string(),
                width.to_string(),
                label.to_string(),
                "DSS-RAM".to_string(),
                format!("{m:.1}"),
            ]);
            rows.push(Json::obj([
                ("knob", "stripe_width".into()),
                ("value", width.into()),
                ("workload", label.into()),
                ("time_s", m.into()),
            ]));
        }
    }

    // Scheduler gravity threshold: chasing KB-scale locality unbalances
    // compute-heavy stages (the fig10 lesson).
    for threshold_mb in [0.0f64, 1.0, 8.0, 64.0] {
        let mut sum = 0.0;
        for r in 0..runs {
            let mut spec = RunSpec::cluster(SystemKind::WossRam, seed);
            spec.seed = seed.wrapping_add(r as u64 * 6151);
            let dock = ModFtDock::default();
            // Thread the threshold through a custom scheduler.
            let wf = dock.build();
            let mut cluster = crate::sim::Cluster::new(
                spec.nodes,
                crate::sim::DiskKind::RamDisk,
                &spec.calib,
            );
            let mut inter =
                crate::storage::standard_deployment(&cluster, true, true, spec.seed);
            let mut backend = crate::nfs::NfsServer::new(&spec.calib);
            let mut sched = crate::workflow::scheduler::LocationAware::new();
            sched.min_gravity_bytes = threshold_mb * 1048576.0;
            let result = crate::workflow::engine::run_workflow(
                &mut cluster,
                &mut inter,
                &mut backend,
                &mut sched,
                EngineConfig::woss(spec.seed),
                &wf,
            )
            .unwrap();
            sum += result.makespan;
        }
        let m = sum / runs as f64;
        table.row([
            "min_gravity".to_string(),
            format!("{threshold_mb} MB"),
            "modFTDock".to_string(),
            "WOSS-RAM".to_string(),
            format!("{m:.1}"),
        ]);
        rows.push(Json::obj([
            ("knob", "min_gravity_mb".into()),
            ("value", threshold_mb.into()),
            ("workload", "modFTDock".into()),
            ("time_s", m.into()),
        ]));
    }

    Report {
        id: "ablation",
        title: "Design-choice ablations",
        table,
        json: Json::obj([
            ("id", "ablation".into()),
            ("runs", runs.into()),
            ("rows", Json::Arr(rows)),
        ]),
        expectation: "stripe width 1 hot-spots the broadcast; very wide striping costs the pipeline nothing but kills the broadcast baseline's realism; a ~8 MB gravity floor avoids compute imbalance from chasing KB-scale files",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_runs() {
        // Smoke: one repetition each; asserts only internal consistency.
        for id in ids() {
            let report = run(id, 1, 42).expect("known id");
            assert!(!report.table.is_empty(), "{id} produced no rows");
            assert!(report.json.get("rows").is_some());
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run("fig99", 1, 1).is_none());
    }

    #[test]
    fn fig5_ordering_holds() {
        let r = fig5(2, 7);
        let rows = match r.json.get("rows") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("rows"),
        };
        let get = |name: &str| -> f64 {
            rows.iter()
                .find(|r| r.get("system").and_then(Json::as_str) == Some(name))
                .and_then(|r| r.get("workflow_s"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert!(get("WOSS-RAM") < get("DSS-RAM"));
        assert!(get("DSS-RAM") < get("NFS"));
        assert!(get("NFS") / get("WOSS-RAM") > 5.0, "order-of-magnitude gap");
        let local = get("local");
        assert!((get("WOSS-RAM") - local).abs() / local < 0.25, "WOSS ≈ local");
    }

    #[test]
    fn table6_shards_throughput_scales() {
        let r = table6_shards(1, 9);
        let rows = match r.json.get("rows") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("rows"),
        };
        let thr = |knob: &str, value: f64| -> f64 {
            rows.iter()
                .find(|row| {
                    row.get("knob").and_then(Json::as_str) == Some(knob)
                        && row.get("value").and_then(Json::as_f64) == Some(value)
                })
                .and_then(|row| row.get("setattr_per_s"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        let s1 = thr("manager_shards", 1.0);
        let s8 = thr("manager_shards", 8.0);
        assert!(
            s8 > 4.0 * s1,
            "8 shards must scale setattr throughput well past 4x: {s8:.0}/s vs {s1:.0}/s"
        );
        let b1 = thr("setattr_batch", 1.0);
        let b16 = thr("setattr_batch", 16.0);
        assert!(
            b16 > 2.0 * b1,
            "batch=16 must amortize the per-RPC cost: {b16:.0}/s vs {b1:.0}/s"
        );
        // The 1-shard serialized row is the Table 6 configuration: the
        // storm must take at least the serial floor of the queue.
        let storm_s = rows
            .iter()
            .find(|row| {
                row.get("knob").and_then(Json::as_str) == Some("manager_shards")
                    && row.get("value").and_then(Json::as_f64) == Some(1.0)
            })
            .and_then(|row| row.get("storm_s"))
            .and_then(Json::as_f64)
            .unwrap();
        let serial_floor = 128.0 * crate::sim::Calib::default().manager_setattr_ms / 1e3;
        assert!(
            storm_s >= serial_floor * 0.99,
            "centralized storm {storm_s:.3}s below the serialized floor {serial_floor:.3}s"
        );
    }

    #[test]
    fn live_throughput_shape_and_semantics() {
        let r = live_throughput(1, 11);
        let rows = match r.json.get("rows") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("rows"),
        };
        assert_eq!(rows.len(), 27, "3 backends × 3 stripe counts × 3 thread counts");
        for row in rows {
            assert!(row.get("read_mbps").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(row.get("write_mbps").and_then(Json::as_f64).unwrap() > 0.0);
            let backend = row.get("backend").and_then(Json::as_str).unwrap();
            assert!(backend == "mem" || backend == "disk" || backend == "seg");
        }
        // Wall-clock magnitudes (scaling factors, the optimistic-vs-
        // pessimistic latency gap) are machine-dependent — a 1-core CI
        // runner time-slices the background pool against the measured
        // writers — so those claims live in the bench output, not in
        // asserts. Here: both semantics produced a positive mean.
        let lat = match r.json.get("latency") {
            Some(Json::Arr(lat)) => lat,
            _ => panic!("latency"),
        };
        let mean = |sem: &str| -> f64 {
            lat.iter()
                .find(|row| row.get("semantics").and_then(Json::as_str) == Some(sem))
                .and_then(|row| row.get("mean_write_us"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert!(mean("optimistic") > 0.0);
        assert!(mean("pessimistic") > 0.0);
    }

    #[test]
    fn live_cache_hint_eviction_beats_lru_and_stays_bounded() {
        let r = live_cache(1, 5);
        let rows = match r.json.get("rows") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("rows"),
        };
        assert_eq!(rows.len(), 12, "3 backends × 2 policies × 2 budgets");
        let field = |row: &Json, key: &str| row.get(key).and_then(Json::as_f64).unwrap();
        let locality = |backend: &str, policy: &str, tight: bool| {
            rows.iter()
                .find(|row| {
                    row.get("backend").and_then(Json::as_str) == Some(backend)
                        && row.get("policy").and_then(Json::as_str) == Some(policy)
                        && (field(row, "cache_kb") == 1024.0) == tight
                })
                .map(|row| field(row, "locality"))
                .unwrap()
        };
        // The acceptance claim: at equal (tight) cache size, hint-aware
        // eviction wins on locality — scratch evicts first, so the
        // durable hot set stays resident while plain LRU churns it.
        // The policy shape holds on every chunk backend.
        for backend in ["mem", "disk", "seg"] {
            assert!(
                locality(backend, "hint", true) > locality(backend, "lru", true),
                "[{backend}] hint {:.2} must beat lru {:.2} at the tight budget",
                locality(backend, "hint", true),
                locality(backend, "lru", true)
            );
        }
        // The cache-policy counters are backend-independent: the tier
        // sits above the ChunkBackend trait, so swapping mem for disk
        // or seg must not change what gets cached or evicted.
        for backend in ["disk", "seg"] {
            assert_eq!(
                locality("mem", "hint", true),
                locality(backend, "hint", true),
                "cache behaviour must be identical across backends ({backend})"
            );
        }
        // Cached bytes stay bounded by the budget in every configuration.
        for row in rows {
            assert!(
                field(row, "peak_resident") <= field(row, "budget"),
                "peak resident {} exceeded budget {}",
                field(row, "peak_resident"),
                field(row, "budget")
            );
        }
        // Disk-penalty recovery: with the cache off every hot read
        // fetches from the remote disk (rounds × files chunk fetches);
        // the hint-aware cache collapses that to the first round and
        // serves the rest as hits — no disk read on a cache hit.
        let penalty = match r.json.get("disk_penalty") {
            Some(Json::Arr(p)) => p,
            _ => panic!("disk_penalty"),
        };
        let pfield = |config: &str, key: &str| -> f64 {
            penalty
                .iter()
                .find(|row| row.get("config").and_then(Json::as_str) == Some(config))
                .and_then(|row| row.get(key))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(pfield("disk/no-cache", "remote_reads"), 24.0, "6 rounds × 4 files");
        assert_eq!(pfield("disk/hint-cache", "remote_reads"), 4.0, "first round only");
        assert_eq!(pfield("disk/hint-cache", "cache_hits"), 20.0, "rest served hot");
        assert_eq!(
            pfield("disk/no-cache", "remote_reads"),
            pfield("mem/no-cache", "remote_reads"),
            "backends agree on every counter; only the medium differs"
        );
        // Prefetch made the pipeline handoff fully node-local.
        let pf = r.json.get("prefetch").unwrap();
        assert_eq!(pf.get("queued").and_then(Json::as_f64), Some(4.0));
        assert_eq!(pf.get("prefetched").and_then(Json::as_f64), Some(4.0));
        assert_eq!(pf.get("local_reads").and_then(Json::as_f64), Some(4.0));
        // Every Consumers=1 scratch file died after its read.
        let rc = r.json.get("reclaim").unwrap();
        assert_eq!(rc.get("files_reclaimed").and_then(Json::as_f64), Some(6.0));
        assert_eq!(
            rc.get("bytes_reclaimed").and_then(Json::as_f64),
            Some(6.0 * 256.0 * 1024.0)
        );
    }

    #[test]
    fn table6_ladder_overheads() {
        let r = table6(1, 3);
        let rows = match r.json.get("rows") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("rows"),
        };
        let t: Vec<f64> = rows
            .iter()
            .map(|r| r.get("total_s").and_then(Json::as_f64).unwrap())
            .collect();
        // Each overhead rung sits at or above plain DSS; useful tags win.
        for rung in &t[1..5] {
            assert!(*rung >= t[0] * 0.99, "overhead rung {rung} below DSS {}", t[0]);
        }
        assert!(t[5] < t[4], "useful tags must beat useless tags");
    }
}
