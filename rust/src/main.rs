//! `woss` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `experiment <id|all>` — regenerate a paper figure/table on the
//!   simulated testbed (`woss list` shows ids). `--runs`, `--seed`,
//!   `--json out.json`, `--config file.toml`, `--profile cluster|bgp`.
//! * `live` — run a workload on the live engine (real bytes, real PJRT
//!   kernels): `--workload pipeline|montage`, `--nodes`, `--workers`,
//!   `--stripes` (manager lock stripes), `--repl-workers` (background
//!   replication threads), `--io-workers` (disk I/O pool threads;
//!   1 = serial data path), `--cache-mb` (per-node hot-chunk cache
//!   budget; 0 = off), `--cache-policy lru|hint` (eviction policy),
//!   `--lifetime` (tag + enforce scratch reclamation), `--backend
//!   mem|disk|seg` (chunk backend; `disk` spills one file per chunk,
//!   `seg` packs chunks into a few append-only segment logs per node),
//!   `--data-dir PATH` (persistent-backend root; omitted = a temp
//!   directory removed on exit), `--adaptive on|off` (load-aware
//!   placement + read scheduling fed by live node signals; `off`, the
//!   default, reproduces the static decisions byte-for-byte),
//!   `--fingerprint-file PATH` (record output
//!   fingerprints for a later restart check), `--clean-shutdown`
//!   (write the namespace snapshot + CLEAN marker before exiting),
//!   `--flush-timeout-ms N` (bound the replication flush barrier;
//!   0 = wait forever, timeouts surface in the run report).
//! * `live --reopen --data-dir PATH` — recover a persistent store a
//!   previous process left behind (cleanly or not; the backend kind
//!   comes from its `store.meta`): replay manifests/segment logs +
//!   journal or snapshot, print what survived, verify recorded
//!   fingerprints when `--fingerprint-file` names a file, and shut
//!   down clean.
//! * `live --connect ADDR` — run the same workload against a running
//!   `woss managerd` over the wire protocol instead of an in-process
//!   store (`ADDR` is `unix:/path.sock` or `tcp:host:port`);
//!   `--clean-shutdown` asks the daemon to snapshot and exit.
//! * `noded --listen ADDR` — a chunk-node daemon: one
//!   [`woss::live::ChunkBackend`] served over the length-prefixed wire
//!   protocol. `--backend mem|disk|seg`, `--data-dir PATH` (required
//!   for persistent backends), `--reopen` (salvage what a previous —
//!   possibly SIGKILLed — daemon left behind).
//! * `managerd --listen ADDR --nodes A,B,C` — the metadata/placement
//!   daemon: a full `LiveStore` whose node tier is remote `noded`
//!   processes (comma-separated addresses). Takes the usual store
//!   tuning flags (`--capacity-mb`, `--stripes`, `--repl-workers`,
//!   `--io-workers`, `--cache-mb`, `--cache-policy`, `--lifetime`,
//!   `--adaptive on|off`, `--flush-timeout-ms`, `--no-hints`).
//! * `scenario <name|all>` — run hostile-scenario workloads (fault
//!   injection + live node churn) against the live store: `--list`
//!   prints the scenario names, `--seed N` replays a schedule,
//!   `--backend mem|disk|seg`, `--data-dir PATH` (persistent root), `--quick`
//!   (smoke sizes), `--io-workers N` (disk I/O pool threads),
//!   `--adaptive on|off` (primary-run mode; the skew scenarios
//!   dual-run both modes either way and record both p99 columns),
//!   `--transport inproc|socket` (socket = real `noded` daemon
//!   processes per node, churn by SIGKILL), `--wire-bench` (also run
//!   the socket transport on wire-tracked scenarios and record its
//!   read p99), `--json out.json` (the `woss-scenarios-v3` document
//!   `BENCH_scenarios.json` tracks).
//! * `bench-check` — validate tracked bench results:
//!   `--scenarios BENCH_scenarios.json --live BENCH_live.json`.
//! * `list` — experiment ids.
//! * `calib` — print the active calibration.

use std::sync::Arc;

use anyhow::{anyhow, Result};
use woss::bench::experiments;
use woss::coordinator::{config, report};
use woss::dispatch::Registry;
use woss::live::{
    connect_node_tier, open_node_host, serve_manager, serve_node, BackendKind, CachePolicy,
    EngineOptions, LiveEngine, LiveStore, LiveTuning, ManagerService, RemoteStore, RpcAddr,
    StoreHandle,
};
use woss::scenario;
use woss::util::cli::Args;
use woss::workloads;

/// Parse `--adaptive on|off` (absent = off: the static decisions the
/// store has always made, byte-for-byte).
fn parse_adaptive(args: &Args) -> Result<bool> {
    match args.get("adaptive") {
        None | Some("off") => Ok(false),
        Some("on") => Ok(true),
        Some(other) => Err(anyhow!("unknown --adaptive '{other}' (on|off)")),
    }
}

/// Parse `--flush-timeout-ms N` (absent or 0 = wait forever, the
/// behaviour every prior release had).
fn parse_flush_timeout(args: &Args) -> Option<u64> {
    match args.get_parse("flush-timeout-ms", 0u64) {
        0 => None,
        ms => Some(ms),
    }
}

/// Parse `--listen ADDR` / a required socket address option.
fn parse_addr(args: &Args, key: &str, usage: &str) -> Result<RpcAddr> {
    args.get(key)
        .ok_or_else(|| anyhow!("{usage}"))?
        .parse::<RpcAddr>()
        .map_err(|e| anyhow!(e))
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("experiment") => cmd_experiment(args),
        Some("live") => cmd_live(args),
        Some("noded") => cmd_noded(args),
        Some("managerd") => cmd_managerd(args),
        Some("scenario") => cmd_scenario(args),
        Some("bench-check") => cmd_bench_check(args),
        Some("list") => {
            for id in experiments::ids() {
                println!("{id}");
            }
            Ok(())
        }
        Some("calib") => {
            let calib = config::load_calib(
                args.get_or("profile", "cluster"),
                args.get("config"),
            )?;
            println!("{calib:#?}");
            Ok(())
        }
        Some(other) => Err(anyhow!(
            "unknown command '{other}' (experiment|live|noded|managerd|scenario|bench-check|list|calib)"
        )),
        None => {
            println!("woss — workflow-optimized storage system (paper reproduction)");
            println!("usage: woss <experiment|live|noded|managerd|scenario|bench-check|list|calib> [options]");
            println!("  woss experiment all --runs 5 --json results.json");
            println!("  woss experiment live --runs 2 --json BENCH_live.json");
            println!("  woss experiment fig5 --runs 20");
            println!("  woss live --workload montage --nodes 8 --workers 8 --stripes 8 --repl-workers 2");
            println!("  woss live --workload pipeline --cache-mb 64 --cache-policy hint --lifetime");
            println!("  woss live --workload pipeline --backend disk --data-dir /tmp/woss --cache-mb 64");
            println!("  woss live --workload montage --backend disk --io-workers 4");
            println!("  woss live --workload montage --backend seg --data-dir /tmp/woss-seg");
            println!("  woss live --reopen --data-dir /tmp/woss    # recover a store left behind");
            println!("  woss noded --listen unix:/tmp/woss-n0.sock --backend seg --data-dir /tmp/woss-n0");
            println!("  woss managerd --listen unix:/tmp/woss-mgr.sock --nodes unix:/tmp/woss-n0.sock,unix:/tmp/woss-n1.sock");
            println!("  woss live --connect unix:/tmp/woss-mgr.sock --workload pipeline");
            println!("  woss scenario --list                       # hostile-scenario names");
            println!("  woss scenario kill_recover --quick --transport socket --backend seg");
            println!("  woss scenario all --seed 7 --json BENCH_scenarios.json");
            println!("  woss scenario kill_recover --quick --backend disk --data-dir /tmp/woss-scn");
            println!("  woss bench-check --scenarios BENCH_scenarios.json --live BENCH_live.json");
            Ok(())
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("usage: woss experiment <id|all>"))?;
    let runs = args.get_parse("runs", 5usize);
    let seed = args.get_parse("seed", 42u64);
    // Config overrides apply through the experiment drivers' defaults;
    // the drivers construct their own testbeds, so overrides are
    // currently limited to validating the file parses (future work:
    // thread the calib through every driver).
    if let Some(cfg) = args.get("config") {
        let _ = config::load_calib(args.get_or("profile", "cluster"), Some(cfg))?;
    }

    let reports = if id == "all" {
        experiments::run_all(runs, seed)
    } else if id == "live" {
        // The live-engine group: the measurements `BENCH_live.json`
        // tracks (throughput, cache behaviour, recovery timings).
        experiments::live_ids()
            .into_iter()
            .map(|i| experiments::run(i, runs, seed).expect("live group id"))
            .collect()
    } else {
        vec![experiments::run(id, runs, seed)
            .ok_or_else(|| anyhow!("unknown experiment '{id}'; see `woss list`"))?]
    };
    report::print_reports(&reports);
    if let Some(path) = args.get("json") {
        report::write_reports(&reports, std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_live(args: &Args) -> Result<()> {
    if args.has_flag("reopen") {
        return cmd_live_reopen(args);
    }
    if args.get("connect").is_some() {
        return cmd_live_connect(args);
    }
    let nodes = args.get_parse("nodes", 8usize);
    let workers = args.get_parse("workers", 8usize);
    let defaults = LiveTuning::default();
    let stripes = args.get_parse("stripes", defaults.stripes);
    let repl_workers = args.get_parse("repl-workers", defaults.repl_workers);
    let io_workers = args.get_parse("io-workers", defaults.io_workers);
    let cache_mb = args.get_parse("cache-mb", 0u64);
    let cache_policy = match args.get_or("cache-policy", "hint") {
        "lru" => CachePolicy::Lru,
        "hint" => CachePolicy::HintAware,
        other => return Err(anyhow!("unknown --cache-policy '{other}' (lru|hint)")),
    };
    let lifetime = args.has_flag("lifetime");
    let data_dir = args.get("data-dir").map(std::path::PathBuf::from);
    let backend = match args.get("backend") {
        Some(raw) => raw.parse::<BackendKind>().map_err(|e| anyhow!(e))?,
        // --data-dir only makes sense for the disk backend; giving it
        // without --backend selects disk.
        None if data_dir.is_some() => BackendKind::Disk,
        None => BackendKind::from_env(),
    };
    if backend == BackendKind::Memory && data_dir.is_some() {
        return Err(anyhow!("--data-dir requires --backend disk|seg"));
    }
    let workload = args.get_or("workload", "pipeline");
    let hints = !args.has_flag("no-hints");
    let adaptive = parse_adaptive(args)?;

    let wf = match workload {
        "pipeline" => workloads::pipeline(nodes.min(8), 0.01, hints),
        "montage" => workloads::Montage {
            inputs: 12,
            hints,
            scale: 0.05,
        }
        .build(),
        other => return Err(anyhow!("unknown workload '{other}' (pipeline|montage)")),
    };

    let tuning = LiveTuning {
        stripes,
        repl_workers,
        cache_bytes: if cache_mb > 0 {
            Some(cache_mb * 1024 * 1024)
        } else {
            None
        },
        cache_policy,
        lifetime,
        backend,
        data_dir,
        fault: None,
        io_workers,
        adaptive,
        flush_timeout_ms: parse_flush_timeout(args),
    };
    let registry = if hints {
        Registry::woss()
    } else {
        Registry::baseline()
    };
    let store = LiveStore::try_with_tuning(registry, nodes, u64::MAX / 2, tuning)
        .map_err(|e| anyhow!("bring up {} backend: {e}", backend.label()))?;
    let store_data_dir = store.data_dir().map(|p| p.display().to_string());
    let engine = LiveEngine::with_options(
        store,
        workers,
        EngineOptions {
            lifetime,
            prefetch: cache_mb > 0,
        },
    )?;
    let rep = engine.run(&wf)?;
    let verified = engine.verify(&rep)?;
    println!("live run: {} tasks in {:.2}s", rep.tasks, rep.elapsed_secs);
    println!(
        "  storage: {:.1} MB written, {:.1} MB read, {:.1} MB/s aggregate",
        rep.bytes_written as f64 / 1048576.0,
        rep.bytes_read as f64 / 1048576.0,
        rep.throughput_mbps()
    );
    println!(
        "  locality: {:.0}% of chunk reads local ({} local / {} remote)",
        rep.locality() * 100.0,
        rep.local_reads,
        rep.remote_reads
    );
    println!(
        "  replication: {} replica copies drained in the background ({} stripes, {} repl workers, {} io workers)",
        rep.bg_replicas, stripes, repl_workers, io_workers
    );
    if adaptive {
        println!("  adaptive: load-aware placement + read scheduling on");
    }
    println!(
        "  latency µs: put p50/p95/p99 {:.0}/{:.0}/{:.0}, get {:.0}/{:.0}/{:.0}, spill {:.0}/{:.0}/{:.0}",
        rep.put_p50_us,
        rep.put_p95_us,
        rep.put_p99_us,
        rep.get_p50_us,
        rep.get_p95_us,
        rep.get_p99_us,
        rep.spill_p50_us,
        rep.spill_p95_us,
        rep.spill_p99_us
    );
    match &store_data_dir {
        Some(dir) => println!(
            "  backend: {} tier under {dir} ({} scratch chunks written back under pressure)",
            rep.backend, rep.spilled_chunks
        ),
        None => println!("  backend: {} tier", rep.backend),
    }
    if cache_mb > 0 {
        println!(
            "  cache: {} hits, {} chunks prefetched, peak {:.1} MB resident (budget {cache_mb} MB/node, {:?} eviction)",
            rep.cache_hits,
            rep.prefetched_chunks,
            rep.peak_cache_bytes as f64 / 1048576.0,
            cache_policy
        );
    }
    if lifetime {
        println!(
            "  lifetime: {} scratch intermediates reclaimed ({:.1} MB returned before run end)",
            rep.files_reclaimed,
            rep.bytes_reclaimed as f64 / 1048576.0
        );
    }
    if rep.read_errors > 0 {
        println!(
            "  faults: {} chunk reads failed on a present chunk (failed over)",
            rep.read_errors
        );
    }
    if rep.flush_timeouts > 0 {
        println!(
            "  flush: {} barrier waits hit the --flush-timeout-ms deadline",
            rep.flush_timeouts
        );
    }
    println!("  kernels: {:?}", rep.kernel_execs);
    println!("  integrity: {verified} files verified by checksum kernel");
    if let Some(fp_path) = args.get("fingerprint-file") {
        write_fingerprints(std::path::Path::new(fp_path), &rep.fingerprints)?;
        println!(
            "  fingerprints: {} recorded to {fp_path}",
            rep.fingerprints.len()
        );
    }
    if args.has_flag("clean-shutdown") {
        engine.store().shutdown();
        println!("  shutdown: clean (namespace snapshot + CLEAN marker written)");
    }
    Ok(())
}

/// `woss live --reopen --data-dir PATH`: recover a disk-backed store a
/// previous process left behind, report what survived, optionally
/// verify recorded fingerprints, and leave the store cleanly shut down
/// (so the next reopen takes the snapshot path).
fn cmd_live_reopen(args: &Args) -> Result<()> {
    let data_dir = args
        .get("data-dir")
        .ok_or_else(|| anyhow!("--reopen requires --data-dir PATH"))?;
    let defaults = LiveTuning::default();
    let cache_mb = args.get_parse("cache-mb", 0u64);
    let cache_policy = match args.get_or("cache-policy", "hint") {
        "lru" => CachePolicy::Lru,
        "hint" => CachePolicy::HintAware,
        other => return Err(anyhow!("unknown --cache-policy '{other}' (lru|hint)")),
    };
    let tuning = LiveTuning {
        stripes: args.get_parse("stripes", defaults.stripes),
        repl_workers: args.get_parse("repl-workers", defaults.repl_workers),
        io_workers: args.get_parse("io-workers", defaults.io_workers),
        cache_bytes: if cache_mb > 0 {
            Some(cache_mb * 1024 * 1024)
        } else {
            None
        },
        cache_policy,
        lifetime: args.has_flag("lifetime"),
        adaptive: parse_adaptive(args)?,
        ..defaults
    };
    let registry = if args.has_flag("no-hints") {
        Registry::baseline()
    } else {
        Registry::woss()
    };
    let store = LiveStore::reopen_with(registry, std::path::Path::new(data_dir), tuning)
        .map_err(|e| anyhow!("reopen {data_dir}: {e}"))?;
    let recovery = store.recovery_report().cloned().unwrap_or_default();
    println!(
        "reopened {data_dir} after a {} shutdown",
        if recovery.clean { "clean" } else { "crash (journal salvage)" }
    );
    println!(
        "  files: {} recovered ({:.1} MB), {} dropped as torn, {} scratch discarded",
        recovery.files_recovered,
        recovery.bytes_recovered as f64 / 1048576.0,
        recovery.files_dropped,
        recovery.scratch_discarded
    );
    println!(
        "  chunks: {} verified, {} dropped (torn manifest / corrupt / orphaned / unclaimed)",
        recovery.chunks_recovered, recovery.chunks_dropped
    );
    match args.get("fingerprint-file") {
        Some(fp_path) => {
            let fps = read_fingerprints(std::path::Path::new(fp_path))?;
            let engine = LiveEngine::new(store, 1)?;
            let verified = engine
                .verify_fingerprints(&fps)
                .map_err(|e| anyhow!("recovered fingerprints diverge: {e}"))?;
            println!(
                "  integrity: {verified}/{} recovered fingerprints match",
                fps.len()
            );
            engine.store().shutdown();
        }
        None => store.shutdown(),
    }
    println!("  shutdown: clean (next reopen takes the snapshot path)");
    Ok(())
}

/// `woss live --connect ADDR`: the same workload driver, but the store
/// is a running `woss managerd` reached over the wire protocol — the
/// engine, hints, and end-to-end verification are unchanged; only the
/// transport under the service boundary differs.
fn cmd_live_connect(args: &Args) -> Result<()> {
    let addr = parse_addr(args, "connect", "usage: woss live --connect unix:/path.sock|tcp:host:port")?;
    let workers = args.get_parse("workers", 8usize);
    let hints = !args.has_flag("no-hints");
    let workload = args.get_or("workload", "pipeline");

    // Retry the handshake briefly: the daemon may still be binding or
    // waiting on its own node tier.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let store = loop {
        match RemoteStore::connect(addr.clone()) {
            Ok(s) => break s,
            Err(_) if std::time::Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            Err(e) => return Err(anyhow!("connect {addr}: {e}")),
        }
    };
    let handle = StoreHandle::Remote(Arc::new(store));
    let info = handle.info();

    let wf = match workload {
        "pipeline" => workloads::pipeline(info.n_nodes.min(8), 0.01, hints),
        "montage" => workloads::Montage {
            inputs: 12,
            hints,
            scale: 0.05,
        }
        .build(),
        other => return Err(anyhow!("unknown workload '{other}' (pipeline|montage)")),
    };
    let engine = LiveEngine::with_handle(
        handle.clone(),
        workers,
        EngineOptions {
            lifetime: info.lifetime_enabled,
            prefetch: info.cache_enabled,
        },
    )?;
    let rep = engine.run(&wf)?;
    let verified = engine.verify(&rep)?;
    println!(
        "live run over {addr}: {} tasks in {:.2}s ({} nodes, {} backend, wire transport)",
        rep.tasks, rep.elapsed_secs, info.n_nodes, rep.backend
    );
    println!(
        "  storage: {:.1} MB written, {:.1} MB read, {:.1} MB/s aggregate",
        rep.bytes_written as f64 / 1048576.0,
        rep.bytes_read as f64 / 1048576.0,
        rep.throughput_mbps()
    );
    println!(
        "  locality: {:.0}% of chunk reads local ({} local / {} remote)",
        rep.locality() * 100.0,
        rep.local_reads,
        rep.remote_reads
    );
    if rep.flush_timeouts > 0 {
        println!(
            "  flush: {} barrier waits hit the daemon's flush deadline",
            rep.flush_timeouts
        );
    }
    println!("  integrity: {verified} files verified by checksum kernel");
    if let Some(fp_path) = args.get("fingerprint-file") {
        write_fingerprints(std::path::Path::new(fp_path), &rep.fingerprints)?;
        println!(
            "  fingerprints: {} recorded to {fp_path}",
            rep.fingerprints.len()
        );
    }
    if args.has_flag("clean-shutdown") {
        engine.handle().svc().shutdown_store();
        println!("  shutdown: daemon asked to snapshot and exit");
    }
    Ok(())
}

/// `woss noded --listen ADDR [--backend mem|disk|seg] [--data-dir PATH]
/// [--reopen]`: serve one chunk node over the wire protocol until a
/// `Shutdown` request (or a signal) stops it. With `--reopen` the
/// backend takes the salvage path over whatever a previous — possibly
/// SIGKILLed — daemon left under `--data-dir`.
fn cmd_noded(args: &Args) -> Result<()> {
    let usage = "usage: woss noded --listen unix:/path.sock|tcp:host:port \
                 [--backend mem|disk|seg] [--data-dir PATH] [--reopen]";
    let listen = parse_addr(args, "listen", usage)?;
    let data_dir = args.get("data-dir").map(std::path::PathBuf::from);
    let backend = match args.get("backend") {
        Some(raw) => raw.parse::<BackendKind>().map_err(|e| anyhow!(e))?,
        None if data_dir.is_some() => BackendKind::Disk,
        None => BackendKind::Memory,
    };
    let reopen = args.has_flag("reopen");
    let host = open_node_host(backend, data_dir.as_deref(), reopen)
        .map_err(|e| anyhow!("bring up {} node: {e}", backend.label()))?;
    let server =
        serve_node(listen, Arc::new(host)).map_err(|e| anyhow!("noded listen: {e}"))?;
    println!(
        "noded: {} backend serving on {}{}",
        backend.label(),
        server.addr(),
        if reopen { " (reopened)" } else { "" }
    );
    server.wait();
    Ok(())
}

/// `woss managerd --listen ADDR --nodes A,B,C [tuning flags]`: the
/// metadata/placement daemon — a full [`LiveStore`] whose chunk tier
/// is remote `noded` processes. Serves until a `Shutdown` request,
/// which snapshots the namespace before the process exits.
fn cmd_managerd(args: &Args) -> Result<()> {
    let usage = "usage: woss managerd --listen ADDR --nodes ADDR[,ADDR...] \
                 [--capacity-mb N] [--stripes N] [--repl-workers N] [--io-workers N] \
                 [--cache-mb N] [--cache-policy lru|hint] [--lifetime] \
                 [--adaptive on|off] [--flush-timeout-ms N] [--no-hints]";
    let listen = parse_addr(args, "listen", usage)?;
    let addrs = args
        .get("nodes")
        .ok_or_else(|| anyhow!(usage))?
        .split(',')
        .map(|s| s.trim().parse::<RpcAddr>())
        .collect::<std::result::Result<Vec<_>, String>>()
        .map_err(|e| anyhow!(e))?;
    let (backends, kind) = connect_node_tier(&addrs).map_err(|e| anyhow!(e))?;
    let defaults = LiveTuning::default();
    let cache_mb = args.get_parse("cache-mb", 0u64);
    let cache_policy = match args.get_or("cache-policy", "hint") {
        "lru" => CachePolicy::Lru,
        "hint" => CachePolicy::HintAware,
        other => return Err(anyhow!("unknown --cache-policy '{other}' (lru|hint)")),
    };
    let tuning = LiveTuning {
        stripes: args.get_parse("stripes", defaults.stripes),
        repl_workers: args.get_parse("repl-workers", defaults.repl_workers),
        io_workers: args.get_parse("io-workers", defaults.io_workers),
        cache_bytes: if cache_mb > 0 {
            Some(cache_mb * 1024 * 1024)
        } else {
            None
        },
        cache_policy,
        lifetime: args.has_flag("lifetime"),
        backend: kind,
        adaptive: parse_adaptive(args)?,
        flush_timeout_ms: parse_flush_timeout(args),
        ..defaults
    };
    let capacity = match args.get_parse("capacity-mb", 0u64) {
        0 => u64::MAX / 2,
        mb => mb * 1024 * 1024,
    };
    let registry = if args.has_flag("no-hints") {
        Registry::baseline()
    } else {
        Registry::woss()
    };
    let n = addrs.len();
    let store = LiveStore::with_backends(registry, backends, kind, capacity, tuning);
    let server =
        serve_manager(listen, Arc::new(store)).map_err(|e| anyhow!("managerd listen: {e}"))?;
    println!(
        "managerd: {n} {} nodes behind {}",
        kind.label(),
        server.addr()
    );
    server.wait();
    Ok(())
}

/// `woss scenario <name|all> [--list] [--seed N] [--backend mem|disk|seg]
/// [--data-dir PATH] [--quick] [--io-workers N] [--adaptive on|off]
/// [--transport inproc|socket] [--wire-bench] [--json PATH]`: run the
/// hostile-scenario harness and optionally emit the
/// `woss-scenarios-v3` results document. Comma-separated names run
/// a subset.
fn cmd_scenario(args: &Args) -> Result<()> {
    if args.has_flag("list") {
        for name in scenario::names() {
            println!("{name}");
        }
        return Ok(());
    }
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let data_dir = args.get("data-dir").map(std::path::PathBuf::from);
    let backend = match args.get("backend") {
        Some(raw) => raw.parse::<BackendKind>().map_err(|e| anyhow!(e))?,
        None if data_dir.is_some() => BackendKind::Disk,
        None => BackendKind::from_env(),
    };
    let cfg = scenario::ScenarioConfig {
        seed: args.get_parse("seed", 7u64),
        backend,
        data_dir,
        quick: args.has_flag("quick"),
        io_workers: args.get_parse("io-workers", 1usize),
        adaptive: parse_adaptive(args)?,
        transport: args
            .get_or("transport", "inproc")
            .parse()
            .map_err(|e: String| anyhow!(e))?,
        wire_bench: args.has_flag("wire-bench"),
    };
    let names: Vec<&str> = if which == "all" {
        scenario::names()
    } else {
        which.split(',').collect()
    };
    let mut reports = Vec::new();
    for name in names {
        let rep = scenario::run(name, &cfg).map_err(|e| anyhow!("scenario {name}: {e}"))?;
        println!("{}", rep.summary_line());
        if !rep.clean() {
            return Err(anyhow!("scenario {name} closed with a dirty audit"));
        }
        reports.push(rep);
    }
    if let Some(path) = args.get("json") {
        let doc = scenario::results_json(&reports, cfg.seed);
        std::fs::write(path, doc.to_string_pretty())
            .map_err(|e| anyhow!("write {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `woss bench-check [--scenarios PATH] [--live PATH]`: validate the
/// tracked bench-result documents against their schemas — the CI gate
/// that keeps `BENCH_scenarios.json` / `BENCH_live.json` honest.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let scen_path = args.get_or("scenarios", "BENCH_scenarios.json");
    let text = std::fs::read_to_string(scen_path)
        .map_err(|e| anyhow!("read {scen_path}: {e}"))?;
    scenario::check_scenarios_json(&text).map_err(|e| anyhow!("{scen_path}: {e}"))?;
    println!("{scen_path}: schema {} ok", scenario::SCENARIO_SCHEMA);
    let live_path = args.get_or("live", "BENCH_live.json");
    let text = std::fs::read_to_string(live_path)
        .map_err(|e| anyhow!("read {live_path}: {e}"))?;
    scenario::check_live_json(&text).map_err(|e| anyhow!("{live_path}: {e}"))?;
    println!("{live_path}: live experiment results ok");
    Ok(())
}

/// Record a run's output fingerprints, one `<f32-bits-hex>\t<path>`
/// line each — exact bit round-trip, so a restarted process can verify
/// recovered files byte-for-byte against what the dead one wrote.
fn write_fingerprints(
    path: &std::path::Path,
    fps: &std::collections::BTreeMap<String, f32>,
) -> Result<()> {
    let mut out = String::new();
    for (p, fp) in fps {
        out.push_str(&format!("{:08x}\t{p}\n", fp.to_bits()));
    }
    std::fs::write(path, out).map_err(|e| anyhow!("write {}: {e}", path.display()))
}

/// Parse a fingerprint file written by `write_fingerprints`.
fn read_fingerprints(
    path: &std::path::Path,
) -> Result<std::collections::BTreeMap<String, f32>> {
    let raw = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
    let mut out = std::collections::BTreeMap::new();
    for line in raw.lines() {
        let (bits, p) = line
            .split_once('\t')
            .ok_or_else(|| anyhow!("malformed fingerprint line: {line}"))?;
        let bits = u32::from_str_radix(bits, 16)
            .map_err(|e| anyhow!("malformed fingerprint bits '{bits}': {e}"))?;
        out.insert(p.to_string(), f32::from_bits(bits));
    }
    Ok(out)
}
