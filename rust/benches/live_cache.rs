//! `cargo bench` target for the live cache-tier sweep: locality vs
//! cache budget × eviction policy (hint-aware vs plain LRU), plus the
//! `Pattern=pipeline` prefetch and `Lifetime=scratch` reclamation
//! demonstrations. See rust/src/bench/experiments.rs for the driver.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment("live_cache");
}
