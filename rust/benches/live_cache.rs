//! `cargo bench` target for the live cache-tier sweep: locality vs
//! cache budget × eviction policy (hint-aware vs plain LRU) × chunk
//! backend (in-memory vs file-backed spill tier), the disk-penalty
//! recovery rows, plus the `Pattern=pipeline` prefetch and
//! `Lifetime=scratch` reclamation demonstrations. See
//! rust/src/bench/experiments.rs for the driver.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment("live_cache");
}
