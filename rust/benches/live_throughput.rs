//! `cargo bench` target for the live-store concurrency sweep: read and
//! tagged-write throughput vs chunk backend (mem|disk|seg) × lock-stripe
//! count × thread count, plus optimistic-vs-pessimistic write latency.
//! See rust/src/bench/experiments.rs for the driver.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment("live_throughput");
}
