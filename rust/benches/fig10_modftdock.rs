//! `cargo bench` target regenerating the paper's fig10 on the
//! simulated testbed. See rust/src/bench/experiments.rs for the driver.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment("fig10");
}
