//! Hot-path microbenches: the operations the perf pass (EXPERIMENTS.md
//! §Perf) optimizes. Not paper figures — these time the system's own
//! internals: DES resource reservations, manager metadata ops, placement
//! decisions, full pipeline simulation, scheduler picks, and (when
//! artifacts are present) PJRT kernel execution.

use std::time::Instant;
use woss::bench::{execute, RunSpec, SystemKind};
use woss::dispatch::{PlacementCtx, PlacementState, Registry};
use woss::hints::TagSet;
use woss::sim::{Calib, Cluster, DiskKind, Dur, Metrics, Resource, SimTime};
use woss::storage::{standard_deployment, Manager, NodeId, NodeState, StorageModel};
use woss::workloads;

fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let rate = 1.0 / per;
    println!("{label:46} {:>12.3} µs/op {rate:>14.0} op/s", per * 1e6);
}

fn main() {
    println!("== WOSS hot paths ==");

    time("resource: gap-filling acquire (fifo run)", 100, || {
        let mut r = Resource::new();
        for i in 0..10_000u64 {
            r.acquire(SimTime(i * 100), Dur(100));
        }
    });

    time("resource: acquire with fragmentation", 100, || {
        let mut r = Resource::new();
        for i in 0..5_000u64 {
            // leave gaps, then fill them
            r.acquire(SimTime(i * 200), Dur(50));
        }
        for i in 0..5_000u64 {
            r.acquire(SimTime(i * 200 + 60), Dur(40));
        }
    });

    {
        let calib = Calib::default();
        let mut cluster = Cluster::new(20, DiskKind::RamDisk, &calib);
        let nodes: Vec<NodeState> = (1..20)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: u64::MAX / 2,
                used: 0,
            })
            .collect();
        let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
        let mut metrics = Metrics::new();
        let mut n = 0u64;
        time("manager: create (64MB file, 64 chunks)", 200, || {
            n += 1;
            mgr.create(
                &mut cluster,
                &mut metrics,
                NodeId(1),
                &format!("/bench/{n}"),
                64 << 20,
                TagSet::new(),
                SimTime::ZERO,
            )
            .unwrap();
        });
    }

    {
        let reg = Registry::woss();
        let nodes: Vec<NodeState> = (1..20)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: u64::MAX / 2,
                used: 0,
            })
            .collect();
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let mut state = PlacementState::default();
        time("dispatch: hinted placement decision", 10_000, || {
            let mut ctx = PlacementCtx {
                client: NodeId(3),
                tags: &tags,
                nodes: &nodes,
                state: &mut state,
            };
            let _ = reg.place_chunk(&mut ctx, 0, 1 << 20).unwrap();
        });
    }

    {
        let calib = Calib::default();
        let mut cluster = Cluster::new(20, DiskKind::RamDisk, &calib);
        let mut store = standard_deployment(&cluster, true, true, 1);
        let mut n = 0u64;
        time("storage: 16MB tagged write (sim)", 500, || {
            n += 1;
            store
                .write_file(
                    &mut cluster,
                    NodeId(1 + (n % 19) as usize),
                    &format!("/w/{n}"),
                    16 << 20,
                    &TagSet::from_pairs([("DP", "local")]),
                    SimTime::ZERO,
                )
                .unwrap();
        });
    }

    time("end-to-end: pipeline experiment (95 tasks)", 10, || {
        let wf = workloads::pipeline(19, 1.0, true);
        let r = execute(&RunSpec::cluster(SystemKind::WossRam, 1), &wf);
        assert!(r.makespan > 0.0);
    });

    time("end-to-end: montage experiment (~470 tasks)", 5, || {
        let wf = workloads::Montage::default().build();
        let r = execute(&RunSpec::cluster(SystemKind::WossDisk, 1), &wf);
        assert!(r.makespan > 0.0);
    });

    // Compute kernels (interpreted backend — no artifacts required).
    {
        let dir = woss::runtime::Runtime::artifact_dir();
        let mut rt = woss::runtime::Runtime::load(&dir).unwrap();
        let tile = vec![0.25f32; woss::runtime::TILE_ELEMS];
        time("kernel: stage_transform (256x256 tile)", 10, || {
            rt.stage_transform(&tile, &tile, &tile).unwrap();
        });
        let parts: Vec<f32> = (0..woss::runtime::MERGE_K)
            .flat_map(|_| tile.clone())
            .collect();
        let weights = vec![0.125f32; woss::runtime::MERGE_K];
        time("kernel: reduce_merge (8-way)", 50, || {
            rt.reduce_merge(&parts, &weights).unwrap();
        });
        time("kernel: checksum", 50, || {
            rt.checksum(&tile).unwrap();
        });
    }
}
