//! Shared mini bench harness (offline substitute for criterion).
//!
//! Each paper-figure bench regenerates its experiment a few times,
//! reports wall-clock stats for the regeneration itself, and prints the
//! experiment table so `cargo bench` output doubles as a results log.
//! Sample count: WOSS_BENCH_SAMPLES (default 3).

use std::time::Instant;
use woss::bench::experiments;
use woss::util::Summary;

pub fn samples() -> usize {
    std::env::var("WOSS_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

/// Run one experiment repeatedly, timing regeneration.
pub fn bench_experiment(id: &str) {
    let n = samples();
    let mut wall = Summary::new();
    let mut last = None;
    for s in 0..n {
        let t0 = Instant::now();
        let report = experiments::run(id, 2, 42 + s as u64).expect("known experiment id");
        wall.add(t0.elapsed().as_secs_f64());
        last = Some(report);
    }
    let report = last.unwrap();
    println!("{}", report.table.render());
    println!("(expectation: {})", report.expectation);
    println!(
        "bench {id}: regenerated {n}x in {} per run (min {:.3}s, max {:.3}s)\n",
        woss::util::table::fmt_secs(wall.mean()),
        wall.min(),
        wall.max()
    );
}

#[allow(dead_code)]
fn main() {}
