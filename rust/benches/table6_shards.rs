//! `cargo bench` target for the Table 6 variant: setattr throughput vs
//! manager shard count and batch size. See rust/src/bench/experiments.rs
//! for the driver.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment("table6_shards");
}
