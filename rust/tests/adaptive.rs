//! Adaptive load-aware placement: safety, convergence, and the
//! off-mode contract.
//!
//! Three claims from the feedback-plane design are pinned here:
//!
//! 1. **Never overfill** — cost-based placement may chase cheap
//!    nodes, but a node's capacity is still a hard wall; a pool under
//!    sustained pressure rejects with `NoSpace`, keeps accounting
//!    exact, and every accepted byte stays readable.
//! 2. **Convergence** — the heat tracker widens a steadily-hot file
//!    exactly once and trims it exactly once after it cools; replica
//!    counts must not ping-pong under a steady workload.
//! 3. **Off means off** — with `adaptive: false` the signals are
//!    still collected, but decisions are byte-identical to the static
//!    store on every backend: perturbing every load signal with read
//!    storms must not move a single placement. This is the
//!    trace-equivalence guard for the pre-adaptive behaviour.
//!
//! Workload shapes come from the seeded `tests/common` harness, so a
//! failing schedule replays with `WOSS_TEST_SEED=<seed>`.

mod common;

use woss::dispatch::Registry;
use woss::hints::TagSet;
use woss::live::{BackendKind, LiveStore, LiveTuning};
use woss::scenario::{self, ScenarioConfig};
use woss::storage::{NodeId, StorageError};
use woss::util::Rng;

/// Deterministic payload, distinct per call.
fn payload(rng: &mut Rng, len: usize) -> Vec<u8> {
    let mult = rng.next_u64() | 1;
    (0..len)
        .map(|i| ((i as u64).wrapping_mul(mult) >> 3) as u8)
        .collect()
}

fn adaptive_tuning(backend: BackendKind, adaptive: bool) -> LiveTuning {
    LiveTuning {
        stripes: 4,
        repl_workers: 1,
        backend,
        adaptive,
        ..LiveTuning::default()
    }
}

/// Pull `used=` / `capacity=` out of the `system_status` attribute
/// (served through any existing file's getattr).
fn used_and_capacity(store: &LiveStore, path: &str) -> (u64, u64) {
    let status = store
        .get_xattr(path, woss::hints::SYSTEM_STATUS_ATTR)
        .expect("system_status answers on a live file");
    let field = |prefix: &str| -> u64 {
        status
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix(prefix))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("no '{prefix}' in '{status}'"))
    };
    (field("used="), field("capacity="))
}

/// Claim 1: a tight pool under adaptive placement rejects cleanly
/// instead of overfilling, across several seeded pressure schedules.
#[test]
fn adaptive_placement_never_overfills_a_tight_pool() {
    let (seed, _) = common::seeded_rng("adaptive_placement_never_overfills_a_tight_pool");
    const NODES: usize = 4;
    const NODE_CAPACITY: u64 = 2 << 20;
    for round in 0..3u64 {
        let mut rng = Rng::new(seed ^ (round.wrapping_mul(0x9e37_79b9)));
        let store = LiveStore::try_with_tuning(
            Registry::woss(),
            NODES,
            NODE_CAPACITY,
            adaptive_tuning(BackendKind::Memory, true),
        )
        .expect("bring up tight store");
        let mut accepted: Vec<(String, Vec<u8>)> = Vec::new();
        let mut rejected = 0u32;
        for f in 0..200 {
            let len = 64 * 1024 + rng.gen_range(128 * 1024) as usize;
            let data = payload(&mut rng, len);
            let path = format!("/tight/f{f}");
            let tags = if f % 3 == 0 {
                TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")])
            } else {
                TagSet::new()
            };
            match store.write_file(NodeId(f % NODES), &path, &data, &tags) {
                Ok(_) => accepted.push((path, data)),
                Err(StorageError::NoSpace(_)) => rejected += 1,
                Err(e) => panic!("pressure write failed with non-capacity error: {e}"),
            }
        }
        store.flush_replication();
        assert!(rejected > 0, "schedule never hit capacity — not a pressure test");
        let (used, capacity) = used_and_capacity(&store, &accepted[0].0);
        assert!(
            used <= capacity,
            "pool overfilled: used {used} > capacity {capacity}"
        );
        let audit = store.audit();
        assert!(audit.clean(), "pressure run closed dirty: {audit:?}");
        for (path, data) in &accepted {
            let back = store
                .read_file(NodeId(0), path)
                .unwrap_or_else(|e| panic!("accepted file {path} unreadable: {e}"));
            assert_eq!(&back, data, "accepted bytes for {path} corrupted");
        }
    }
}

/// Claim 2: one steadily-hot file widens once, stays widened while
/// hot, trims once after cooling, and never re-widens from stale heat.
#[test]
fn heat_replicas_converge_without_ping_pong() {
    let (seed, mut rng) = common::seeded_rng("heat_replicas_converge_without_ping_pong");
    const NODES: usize = 4;
    const COLD_FILES: usize = 200;
    let store = LiveStore::woss_with(NODES, adaptive_tuning(BackendKind::Memory, true));
    let hot = "/heat/hot";
    let hot_data = payload(&mut rng, 96 * 1024);
    store
        .write_file(NodeId(0), hot, &hot_data, &TagSet::new())
        .expect("hot write");
    for f in 0..COLD_FILES {
        let data = payload(&mut rng, 8 * 1024);
        store
            .write_file(NodeId(f % NODES), &format!("/heat/cold{f}"), &data, &TagSet::new())
            .expect("cold write");
    }
    let base_holders = store.locations(hot).len();

    // Hot storm: heat crosses the widen threshold early in the storm;
    // the remaining reads must not widen again.
    for i in 0..300 {
        store.read_file(NodeId(i % NODES), hot).expect("hot read");
    }
    store.flush_replication();
    assert_eq!(store.heat_widened(), 1, "steady heat widened more than once");
    assert_eq!(store.heat_trimmed(), 0);
    let widened_holders = store.locations(hot).len();
    assert!(
        widened_holders > base_holders,
        "hot file never gained a replica (still {widened_holders} holders, seed {seed})"
    );

    // Keep it hot: replica count must hold steady, not oscillate.
    for i in 0..300 {
        store.read_file(NodeId(i % NODES), hot).expect("hot read");
    }
    store.flush_replication();
    assert_eq!(store.heat_widened(), 1, "re-widened under a steady workload");
    assert_eq!(store.heat_trimmed(), 0, "trimmed while still hot");
    assert_eq!(store.locations(hot).len(), widened_holders);

    // Cool-down: the op clock advances on cold traffic, the hot
    // file's entry decays, and the next touch trims it back.
    for i in 0..2600 {
        store
            .read_file(NodeId(i % NODES), &format!("/heat/cold{}", i % COLD_FILES))
            .expect("cold read");
    }
    store.read_file(NodeId(0), hot).expect("cooled read");
    store.flush_replication();
    assert_eq!(store.heat_trimmed(), 1, "cooled file was never trimmed");
    assert_eq!(store.heat_widened(), 1, "trim bounced straight back to widen");
    assert_eq!(
        store.locations(hot).len(),
        base_holders,
        "trim did not return to the base replica count"
    );
    assert_eq!(&store.read_file(NodeId(1), hot).unwrap(), &hot_data);

    // Cold traffic must never have earned a replica of its own.
    let audit = store.audit();
    assert!(audit.clean(), "heat lifecycle closed dirty: {audit:?}");
}

/// Claim 3: with `adaptive: false`, saturating every load signal
/// (read storms between write batches) must not move a single
/// placement, change a byte, or trigger a single heat action — the
/// static trace, on every backend.
#[test]
fn adaptive_off_is_trace_equivalent_to_the_static_store() {
    let (seed, _) = common::seeded_rng("adaptive_off_is_trace_equivalent_to_the_static_store");
    const NODES: usize = 4;
    const FILES: usize = 30;
    for backend in [BackendKind::Memory, BackendKind::Disk, BackendKind::Seg] {
        let quiet = LiveStore::woss_with(NODES, adaptive_tuning(backend, false));
        let stormy = LiveStore::woss_with(NODES, adaptive_tuning(backend, false));
        let mut quiet_rng = Rng::new(seed);
        let mut stormy_rng = Rng::new(seed);
        let mut write_batch = |store: &LiveStore, rng: &mut Rng, batch: usize| {
            for f in 0..FILES / 3 {
                let i = batch * (FILES / 3) + f;
                let len = 32 * 1024 + rng.gen_range(96 * 1024) as usize;
                let data = payload(rng, len);
                let tags = match i % 4 {
                    0 => TagSet::from_pairs([("DP", "local")]),
                    1 => TagSet::from_pairs([("DP", "scatter 2")]),
                    2 => TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]),
                    _ => TagSet::new(),
                };
                store
                    .write_file(NodeId(i % NODES), &format!("/eq/f{i}"), &data, &tags)
                    .expect("equivalence write");
            }
        };
        for batch in 0..3 {
            write_batch(&quiet, &mut quiet_rng, batch);
            write_batch(&stormy, &mut stormy_rng, batch);
            // Storm only the second store: every EWMA, queue-depth,
            // hit-rate, and heat signal diverges from the quiet twin.
            for i in 0..400 {
                let f = i % ((batch + 1) * (FILES / 3));
                stormy
                    .read_file(NodeId(i % NODES), &format!("/eq/f{f}"))
                    .expect("storm read");
            }
        }
        quiet.flush_replication();
        stormy.flush_replication();
        for i in 0..FILES {
            let path = format!("/eq/f{i}");
            assert_eq!(
                quiet.locations(&path),
                stormy.locations(&path),
                "[{}] placement of {path} moved with adaptive off (seed {seed})",
                backend.label()
            );
            assert_eq!(
                quiet.read_file(NodeId(0), &path).unwrap(),
                stormy.read_file(NodeId(0), &path).unwrap(),
                "[{}] bytes of {path} diverged",
                backend.label()
            );
        }
        // Off-mode storms must not trigger heat actions or leak the
        // adaptive-only status field.
        assert_eq!(stormy.heat_widened(), 0, "[{}] off-mode widened", backend.label());
        assert_eq!(stormy.heat_trimmed(), 0, "[{}] off-mode trimmed", backend.label());
        let status = stormy
            .get_xattr("/eq/f0", woss::hints::SYSTEM_STATUS_ATTR)
            .expect("system_status");
        assert!(
            !status.contains("load="),
            "[{}] off-mode status leaked adaptive field: {status}",
            backend.label()
        );
        assert!(quiet.audit().clean() && stormy.audit().clean());
    }
}

/// Seeded skew proving ground: the dual-run `hot_skew` scenario must
/// record both mode legs, and the adaptive leg must not lose badly at
/// smoke sizes. (The strict adaptive-beats-static gate runs on the
/// full-size tracked rows in `bench-check`, where p99 has enough
/// samples to be stable; at quick sizes a 2x guard keeps this
/// replayable without flaking on loaded CI boxes.)
#[test]
fn hot_skew_dual_run_records_both_legs_and_adaptive_holds_up() {
    let cfg = ScenarioConfig {
        seed: 7,
        quick: true,
        ..ScenarioConfig::default()
    };
    let rep = scenario::run("hot_skew", &cfg).expect("hot_skew completes");
    assert!(rep.clean(), "hot_skew closed dirty: {:?}", rep.audit);
    assert!(!rep.adaptive, "primary leg follows cfg.adaptive");
    let p99_static = rep.read_p99_ms_static.expect("static p99 recorded");
    let p99_adaptive = rep.read_p99_ms_adaptive.expect("adaptive p99 recorded");
    assert!(p99_static > 0.0 && p99_adaptive > 0.0);
    assert!(
        p99_adaptive <= p99_static * 2.0,
        "adaptive p99 {p99_adaptive:.3} ms blew past static {p99_static:.3} ms at smoke size"
    );

    // The adaptive primary leg reports the same columns and stays clean.
    let rep_on = scenario::run(
        "hot_skew",
        &ScenarioConfig {
            adaptive: true,
            ..cfg.clone()
        },
    )
    .expect("adaptive hot_skew completes");
    assert!(rep_on.clean());
    assert!(rep_on.adaptive);
    assert!(rep_on.read_p99_ms_static.is_some() && rep_on.read_p99_ms_adaptive.is_some());
}
