//! Property-based tests over coordinator invariants (routing, batching,
//! placement, scheduling, accounting), via the in-tree propcheck
//! harness. Replay failures with WOSS_PROP_SEED=<seed>.

use woss::dispatch::{PlacementCtx, PlacementState, Registry};
use woss::hints::TagSet;
use woss::sim::{Calib, Cluster, DiskKind, Dur, Metrics, Resource, SimTime};
use woss::storage::{standard_deployment, Manager, NodeId, NodeState};
use woss::util::propcheck::{forall, forall_noshrink, shrink_vec};
use woss::util::Rng;
use woss::workflow::dag::{TaskSpec, Tier, Workflow};
use woss::workflow::engine::{run_workflow, EngineConfig};
use woss::workflow::scheduler::LocationAware;

/// Resource reservations never overlap, regardless of request order —
/// the gap-filling allocator's core invariant.
#[test]
fn prop_resource_reservations_disjoint() {
    forall(
        "resource-disjoint",
        |rng: &mut Rng| {
            (0..rng.range_usize(1, 60))
                .map(|_| (rng.gen_range(10_000), 1 + rng.gen_range(500)))
                .collect::<Vec<(u64, u64)>>()
        },
        |v| shrink_vec(v),
        |requests| {
            let mut r = Resource::new();
            let mut spans = Vec::new();
            for &(earliest, dur) in requests {
                let s = r.acquire(SimTime(earliest), Dur(dur));
                if s.start.0 < earliest {
                    return false; // must not start early
                }
                spans.push((s.start.0, s.end.0));
            }
            spans.sort_unstable();
            spans.windows(2).all(|w| w[0].1 <= w[1].0)
        },
    );
}

/// Manager capacity accounting: used bytes always equals the sum of
/// live chunks, across arbitrary create/delete sequences.
#[test]
fn prop_manager_accounting_balances() {
    forall_noshrink(
        "manager-accounting",
        |rng: &mut Rng| {
            (0..rng.range_usize(1, 40))
                .map(|_| {
                    (
                        rng.gen_range(3) == 0, // delete?
                        rng.range_usize(0, 8), // path index
                        1 + rng.gen_range(32 << 20),
                    )
                })
                .collect::<Vec<(bool, usize, u64)>>()
        },
        |ops| {
            let calib = Calib::default();
            let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
            let nodes = (1..8)
                .map(|i| NodeState {
                    node: NodeId(i),
                    capacity: u64::MAX / 4,
                    used: 0,
                })
                .collect();
            let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
            let mut metrics = Metrics::new();
            let mut live_bytes: std::collections::BTreeMap<String, u64> = Default::default();
            for (delete, pidx, size) in ops {
                let path = format!("/p{pidx}");
                if *delete {
                    let existed = mgr.delete(&path).is_ok();
                    if existed {
                        live_bytes.remove(&path);
                    }
                } else if !live_bytes.contains_key(&path) {
                    mgr.create(
                        &mut cluster,
                        &mut metrics,
                        NodeId(1),
                        &path,
                        *size,
                        TagSet::new(),
                        SimTime::ZERO,
                    )
                    .unwrap();
                    live_bytes.insert(path, *size);
                }
            }
            let used: u64 = mgr.nodes().iter().map(|n| n.used).sum();
            let expected: u64 = live_bytes.values().sum();
            used == expected
        },
    );
}

/// Placement honors capacity: every chunk of every file lands on a node
/// that had room, and collocation groups stay on one anchor while space
/// remains.
#[test]
fn prop_placement_respects_capacity_and_groups() {
    forall_noshrink(
        "placement-capacity",
        |rng: &mut Rng| {
            let files = rng.range_usize(1, 20);
            (0..files)
                .map(|i| {
                    let hint = match rng.gen_range(3) {
                        0 => Some(format!("collocation g{}", rng.gen_range(2))),
                        1 => Some("local".to_string()),
                        _ => None,
                    };
                    (i, hint, 1 + rng.gen_range(4 << 20))
                })
                .collect::<Vec<(usize, Option<String>, u64)>>()
        },
        |files| {
            let reg = Registry::woss();
            let mut nodes: Vec<NodeState> = (1..6)
                .map(|i| NodeState {
                    node: NodeId(i),
                    capacity: 8 << 20,
                    used: 0,
                })
                .collect();
            let mut state = PlacementState::default();
            let mut anchors: std::collections::BTreeMap<String, NodeId> = Default::default();
            for (i, hint, size) in files {
                let mut tags = TagSet::new();
                if let Some(h) = hint {
                    tags.set("DP", h);
                }
                let mut ctx = PlacementCtx {
                    client: NodeId(1 + (i % 5)),
                    tags: &tags,
                    nodes: &nodes,
                    state: &mut state,
                };
                match reg.place_chunk(&mut ctx, 0, *size) {
                    Some(node) => {
                        let st = nodes.iter_mut().find(|n| n.node == node).unwrap();
                        if st.free() < *size {
                            return false; // placed beyond capacity
                        }
                        st.used += size;
                        if let Some(h) = hint {
                            if let Some(group) = h.strip_prefix("collocation ") {
                                let anchor =
                                    anchors.entry(group.to_string()).or_insert(node);
                                // Sticky while the anchor still fits.
                                if *anchor != node
                                    && nodes
                                        .iter()
                                        .find(|n| n.node == *anchor)
                                        .map(|n| n.free() >= *size)
                                        .unwrap_or(false)
                                {
                                    return false;
                                }
                            }
                        }
                    }
                    None => {
                        // Only acceptable when nothing fits.
                        if nodes.iter().any(|n| n.free() >= *size) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Engine scheduling: every task starts at/after its ready time and
/// after all of its producers finish, under random DAGs.
#[test]
fn prop_engine_respects_dependencies() {
    forall_noshrink(
        "engine-dependencies",
        |rng: &mut Rng| {
            // Random layered DAG: 2-4 layers, 1-6 tasks each.
            let layers = rng.range_usize(2, 5);
            let widths: Vec<usize> =
                (0..layers).map(|_| rng.range_usize(1, 7)).collect();
            let seed = rng.next_u64();
            (widths, seed)
        },
        |(widths, seed)| {
            let mut w = Workflow::new();
            let mut prev: Vec<String> = Vec::new();
            let mut rng = Rng::new(*seed);
            for (layer, &width) in widths.iter().enumerate() {
                let mut current = Vec::new();
                for t in 0..width {
                    let path = format!("/l{layer}t{t}");
                    let mut task =
                        TaskSpec::new(0, &format!("layer{layer}")).compute(0.1);
                    if prev.is_empty() {
                        w.preload(&format!("/backend/in{t}"), 1 << 20);
                        task = task.read(&format!("/backend/in{t}"), Tier::Backend);
                    } else {
                        // Read 1..=2 random files from the previous layer.
                        for _ in 0..rng.range_usize(1, 3.min(prev.len() + 1)) {
                            let src = rng.choose(prev.as_slice());
                            if !task.reads.iter().any(|r| &r.path == src) {
                                task = task.read(src, Tier::Intermediate);
                            }
                        }
                    }
                    task = task.write(&path, Tier::Intermediate, 1 << 20, TagSet::from_pairs([("DP", "local")]));
                    w.push(task);
                    current.push(path);
                }
                prev = current;
            }

            let calib = Calib::default();
            let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
            let mut inter = standard_deployment(&cluster, true, true, *seed);
            let mut backend = woss::nfs::NfsServer::new(&calib);
            let mut sched = LocationAware::new();
            let result = run_workflow(
                &mut cluster,
                &mut inter,
                &mut backend,
                &mut sched,
                EngineConfig::woss(*seed),
                &w,
            )
            .unwrap();

            // Dependencies respected.
            let deps = w.dependencies();
            let by_id: std::collections::BTreeMap<usize, &woss::workflow::TaskRecord> =
                result.tasks.iter().map(|t| (t.id, t)).collect();
            for (b, ds) in deps.iter().enumerate() {
                for a in ds {
                    if by_id[&b].start < by_id[a].end {
                        return false;
                    }
                }
            }
            result.tasks.iter().all(|t| t.start >= t.ready && t.end >= t.start)
        },
    );
}

/// The live store round-trips arbitrary byte patterns under arbitrary
/// hints (no hint may corrupt data).
#[test]
fn prop_live_store_roundtrip_under_any_hints() {
    forall_noshrink(
        "live-roundtrip",
        |rng: &mut Rng| {
            let len = rng.range_usize(1, 2_000_000);
            let seed = rng.next_u64();
            let hint = rng.gen_range(5);
            (len, seed, hint)
        },
        |&(len, seed, hint)| {
            let store = woss::live::LiveStore::woss(5);
            let mut rng = Rng::new(seed);
            let data: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let tags = match hint {
                0 => TagSet::from_pairs([("DP", "local")]),
                1 => TagSet::from_pairs([("DP", "collocation g")]),
                2 => TagSet::from_pairs([("DP", "scatter 2"), ("BlockSize", "64K")]),
                3 => TagSet::from_pairs([("Replication", "3")]),
                _ => TagSet::new(),
            };
            store
                .write_file(NodeId(seed as usize % 5), "/f", &data, &tags)
                .unwrap();
            let back = store.read_file(NodeId((seed as usize + 1) % 5), "/f").unwrap();
            back == data
        },
    );
}

/// Cache-tier residency invariant: every node's cached bytes stay
/// within the configured per-node budget after every operation, under
/// arbitrary write/read/delete interleavings, both eviction policies,
/// and active lifetime reclamation.
#[test]
fn prop_cache_residency_bounded() {
    use woss::live::{CachePolicy, LiveStore, LiveTuning};
    forall_noshrink(
        "cache-residency",
        |rng: &mut Rng| {
            let hint_policy = rng.gen_range(2) == 0;
            let budget = (1 + rng.gen_range(8)) * 128 * 1024; // 128 KiB..1 MiB
            let ops = (0..rng.range_usize(1, 40))
                .map(|_| {
                    (
                        rng.gen_range(4),          // 0-1: write, 2: read, 3: delete
                        rng.range_usize(0, 6),     // path index
                        rng.range_usize(0, 4),     // acting node
                        1 + rng.gen_range(700_000), // file size
                    )
                })
                .collect::<Vec<(u64, usize, usize, u64)>>();
            (hint_policy, budget, ops)
        },
        |(hint_policy, budget, ops)| {
            let store = LiveStore::woss_with(
                4,
                LiveTuning {
                    stripes: 4,
                    repl_workers: 1,
                    cache_bytes: Some(*budget),
                    cache_policy: if *hint_policy {
                        CachePolicy::HintAware
                    } else {
                        CachePolicy::Lru
                    },
                    lifetime: true,
                },
            );
            for &(op, pidx, node, size) in ops {
                let path = format!("/c{pidx}");
                match op {
                    0 => {
                        let tags = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
                        let _ =
                            store.write_file(NodeId(node), &path, &vec![7u8; size as usize], &tags);
                    }
                    1 => {
                        let tags =
                            TagSet::from_pairs([("Pattern", "broadcast"), ("Consumers", "2")]);
                        let _ =
                            store.write_file(NodeId(node), &path, &vec![9u8; size as usize], &tags);
                    }
                    2 => {
                        let _ = store.read_file(NodeId((node + 1) % 4), &path);
                    }
                    _ => {
                        let _ = store.delete(&path);
                    }
                }
                let stats = store.cache_stats();
                if stats.resident.iter().any(|&r| r > *budget) {
                    return false;
                }
                if stats.peak_node_resident > *budget {
                    return false;
                }
            }
            store.flush_replication();
            store.cache_stats().resident.iter().all(|&r| r <= *budget)
        },
    );
}

/// Simulation determinism: identical seeds ⇒ identical results, across
/// every storage configuration.
#[test]
fn prop_simulation_deterministic() {
    forall_noshrink(
        "determinism",
        |rng: &mut Rng| (rng.next_u64(), rng.gen_range(3)),
        |&(seed, sys)| {
            use woss::bench::{execute, RunSpec, SystemKind};
            let system = match sys {
                0 => SystemKind::Nfs,
                1 => SystemKind::DssRam,
                _ => SystemKind::WossRam,
            };
            let hints = system == SystemKind::WossRam;
            let a = execute(
                &RunSpec::cluster(system, seed),
                &woss::workloads::reduce(8, 0.2, hints),
            );
            let b = execute(
                &RunSpec::cluster(system, seed),
                &woss::workloads::reduce(8, 0.2, hints),
            );
            a.makespan == b.makespan
        },
    );
}
