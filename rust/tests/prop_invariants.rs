//! Property-based tests over coordinator invariants (routing, batching,
//! placement, scheduling, accounting), via the in-tree propcheck
//! harness. Replay failures with WOSS_PROP_SEED=<seed>.

use woss::dispatch::{PlacementCtx, PlacementState, Registry};
use woss::hints::TagSet;
use woss::sim::{Calib, Cluster, DiskKind, Dur, Metrics, Resource, SimTime};
use woss::storage::{standard_deployment, Manager, NodeId, NodeState};
use woss::util::propcheck::{forall, forall_noshrink, shrink_vec};
use woss::util::Rng;
use woss::workflow::dag::{TaskSpec, Tier, Workflow};
use woss::workflow::engine::{run_workflow, EngineConfig};
use woss::workflow::scheduler::LocationAware;

/// Resource reservations never overlap, regardless of request order —
/// the gap-filling allocator's core invariant.
#[test]
fn prop_resource_reservations_disjoint() {
    forall(
        "resource-disjoint",
        |rng: &mut Rng| {
            (0..rng.range_usize(1, 60))
                .map(|_| (rng.gen_range(10_000), 1 + rng.gen_range(500)))
                .collect::<Vec<(u64, u64)>>()
        },
        |v| shrink_vec(v),
        |requests| {
            let mut r = Resource::new();
            let mut spans = Vec::new();
            for &(earliest, dur) in requests {
                let s = r.acquire(SimTime(earliest), Dur(dur));
                if s.start.0 < earliest {
                    return false; // must not start early
                }
                spans.push((s.start.0, s.end.0));
            }
            spans.sort_unstable();
            spans.windows(2).all(|w| w[0].1 <= w[1].0)
        },
    );
}

/// Manager capacity accounting: used bytes always equals the sum of
/// live chunks, across arbitrary create/delete sequences.
#[test]
fn prop_manager_accounting_balances() {
    forall_noshrink(
        "manager-accounting",
        |rng: &mut Rng| {
            (0..rng.range_usize(1, 40))
                .map(|_| {
                    (
                        rng.gen_range(3) == 0, // delete?
                        rng.range_usize(0, 8), // path index
                        1 + rng.gen_range(32 << 20),
                    )
                })
                .collect::<Vec<(bool, usize, u64)>>()
        },
        |ops| {
            let calib = Calib::default();
            let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
            let nodes = (1..8)
                .map(|i| NodeState {
                    node: NodeId(i),
                    capacity: u64::MAX / 4,
                    used: 0,
                })
                .collect();
            let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
            let mut metrics = Metrics::new();
            let mut live_bytes: std::collections::BTreeMap<String, u64> = Default::default();
            for (delete, pidx, size) in ops {
                let path = format!("/p{pidx}");
                if *delete {
                    let existed = mgr.delete(&path).is_ok();
                    if existed {
                        live_bytes.remove(&path);
                    }
                } else if !live_bytes.contains_key(&path) {
                    mgr.create(
                        &mut cluster,
                        &mut metrics,
                        NodeId(1),
                        &path,
                        *size,
                        TagSet::new(),
                        SimTime::ZERO,
                    )
                    .unwrap();
                    live_bytes.insert(path, *size);
                }
            }
            let used: u64 = mgr.nodes().iter().map(|n| n.used).sum();
            let expected: u64 = live_bytes.values().sum();
            used == expected
        },
    );
}

/// Placement honors capacity: every chunk of every file lands on a node
/// that had room, and collocation groups stay on one anchor while space
/// remains.
#[test]
fn prop_placement_respects_capacity_and_groups() {
    forall_noshrink(
        "placement-capacity",
        |rng: &mut Rng| {
            let files = rng.range_usize(1, 20);
            (0..files)
                .map(|i| {
                    let hint = match rng.gen_range(3) {
                        0 => Some(format!("collocation g{}", rng.gen_range(2))),
                        1 => Some("local".to_string()),
                        _ => None,
                    };
                    (i, hint, 1 + rng.gen_range(4 << 20))
                })
                .collect::<Vec<(usize, Option<String>, u64)>>()
        },
        |files| {
            let reg = Registry::woss();
            let mut nodes: Vec<NodeState> = (1..6)
                .map(|i| NodeState {
                    node: NodeId(i),
                    capacity: 8 << 20,
                    used: 0,
                })
                .collect();
            let mut state = PlacementState::default();
            let mut anchors: std::collections::BTreeMap<String, NodeId> = Default::default();
            for (i, hint, size) in files {
                let mut tags = TagSet::new();
                if let Some(h) = hint {
                    tags.set("DP", h);
                }
                let mut ctx = PlacementCtx {
                    client: NodeId(1 + (i % 5)),
                    tags: &tags,
                    nodes: &nodes,
                    state: &mut state,
                };
                match reg.place_chunk(&mut ctx, 0, *size) {
                    Some(node) => {
                        let st = nodes.iter_mut().find(|n| n.node == node).unwrap();
                        if st.free() < *size {
                            return false; // placed beyond capacity
                        }
                        st.used += size;
                        if let Some(h) = hint {
                            if let Some(group) = h.strip_prefix("collocation ") {
                                let anchor =
                                    anchors.entry(group.to_string()).or_insert(node);
                                // Sticky while the anchor still fits.
                                if *anchor != node
                                    && nodes
                                        .iter()
                                        .find(|n| n.node == *anchor)
                                        .map(|n| n.free() >= *size)
                                        .unwrap_or(false)
                                {
                                    return false;
                                }
                            }
                        }
                    }
                    None => {
                        // Only acceptable when nothing fits.
                        if nodes.iter().any(|n| n.free() >= *size) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Engine scheduling: every task starts at/after its ready time and
/// after all of its producers finish, under random DAGs.
#[test]
fn prop_engine_respects_dependencies() {
    forall_noshrink(
        "engine-dependencies",
        |rng: &mut Rng| {
            // Random layered DAG: 2-4 layers, 1-6 tasks each.
            let layers = rng.range_usize(2, 5);
            let widths: Vec<usize> =
                (0..layers).map(|_| rng.range_usize(1, 7)).collect();
            let seed = rng.next_u64();
            (widths, seed)
        },
        |(widths, seed)| {
            let mut w = Workflow::new();
            let mut prev: Vec<String> = Vec::new();
            let mut rng = Rng::new(*seed);
            for (layer, &width) in widths.iter().enumerate() {
                let mut current = Vec::new();
                for t in 0..width {
                    let path = format!("/l{layer}t{t}");
                    let mut task =
                        TaskSpec::new(0, &format!("layer{layer}")).compute(0.1);
                    if prev.is_empty() {
                        w.preload(&format!("/backend/in{t}"), 1 << 20);
                        task = task.read(&format!("/backend/in{t}"), Tier::Backend);
                    } else {
                        // Read 1..=2 random files from the previous layer.
                        for _ in 0..rng.range_usize(1, 3.min(prev.len() + 1)) {
                            let src = rng.choose(prev.as_slice());
                            if !task.reads.iter().any(|r| &r.path == src) {
                                task = task.read(src, Tier::Intermediate);
                            }
                        }
                    }
                    task = task.write(&path, Tier::Intermediate, 1 << 20, TagSet::from_pairs([("DP", "local")]));
                    w.push(task);
                    current.push(path);
                }
                prev = current;
            }

            let calib = Calib::default();
            let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
            let mut inter = standard_deployment(&cluster, true, true, *seed);
            let mut backend = woss::nfs::NfsServer::new(&calib);
            let mut sched = LocationAware::new();
            let result = run_workflow(
                &mut cluster,
                &mut inter,
                &mut backend,
                &mut sched,
                EngineConfig::woss(*seed),
                &w,
            )
            .unwrap();

            // Dependencies respected.
            let deps = w.dependencies();
            let by_id: std::collections::BTreeMap<usize, &woss::workflow::TaskRecord> =
                result.tasks.iter().map(|t| (t.id, t)).collect();
            for (b, ds) in deps.iter().enumerate() {
                for a in ds {
                    if by_id[&b].start < by_id[a].end {
                        return false;
                    }
                }
            }
            result.tasks.iter().all(|t| t.start >= t.ready && t.end >= t.start)
        },
    );
}

/// The live store round-trips arbitrary byte patterns under arbitrary
/// hints (no hint may corrupt data).
#[test]
fn prop_live_store_roundtrip_under_any_hints() {
    forall_noshrink(
        "live-roundtrip",
        |rng: &mut Rng| {
            let len = rng.range_usize(1, 2_000_000);
            let seed = rng.next_u64();
            let hint = rng.gen_range(5);
            (len, seed, hint)
        },
        |&(len, seed, hint)| {
            let store = woss::live::LiveStore::woss(5);
            let mut rng = Rng::new(seed);
            let data: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let tags = match hint {
                0 => TagSet::from_pairs([("DP", "local")]),
                1 => TagSet::from_pairs([("DP", "collocation g")]),
                2 => TagSet::from_pairs([("DP", "scatter 2"), ("BlockSize", "64K")]),
                3 => TagSet::from_pairs([("Replication", "3")]),
                _ => TagSet::new(),
            };
            store
                .write_file(NodeId(seed as usize % 5), "/f", &data, &tags)
                .unwrap();
            let back = store.read_file(NodeId((seed as usize + 1) % 5), "/f").unwrap();
            back == data
        },
    );
}

/// Cache-tier residency invariant: every node's cached bytes stay
/// within the configured per-node budget after every operation, under
/// arbitrary write/read/delete interleavings, both eviction policies,
/// and active lifetime reclamation.
#[test]
fn prop_cache_residency_bounded() {
    use woss::live::{CachePolicy, LiveStore, LiveTuning};
    forall_noshrink(
        "cache-residency",
        |rng: &mut Rng| {
            let hint_policy = rng.gen_range(2) == 0;
            let budget = (1 + rng.gen_range(8)) * 128 * 1024; // 128 KiB..1 MiB
            let ops = (0..rng.range_usize(1, 40))
                .map(|_| {
                    (
                        rng.gen_range(4),          // 0-1: write, 2: read, 3: delete
                        rng.range_usize(0, 6),     // path index
                        rng.range_usize(0, 4),     // acting node
                        1 + rng.gen_range(700_000), // file size
                    )
                })
                .collect::<Vec<(u64, usize, usize, u64)>>();
            (hint_policy, budget, ops)
        },
        |(hint_policy, budget, ops)| {
            let store = LiveStore::woss_with(
                4,
                LiveTuning {
                    stripes: 4,
                    repl_workers: 1,
                    cache_bytes: Some(*budget),
                    cache_policy: if *hint_policy {
                        CachePolicy::HintAware
                    } else {
                        CachePolicy::Lru
                    },
                    lifetime: true,
                    ..LiveTuning::default()
                },
            );
            for &(op, pidx, node, size) in ops {
                let path = format!("/c{pidx}");
                match op {
                    0 => {
                        let tags = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
                        let _ =
                            store.write_file(NodeId(node), &path, &vec![7u8; size as usize], &tags);
                    }
                    1 => {
                        let tags =
                            TagSet::from_pairs([("Pattern", "broadcast"), ("Consumers", "2")]);
                        let _ =
                            store.write_file(NodeId(node), &path, &vec![9u8; size as usize], &tags);
                    }
                    2 => {
                        let _ = store.read_file(NodeId((node + 1) % 4), &path);
                    }
                    _ => {
                        let _ = store.delete(&path);
                    }
                }
                let stats = store.cache_stats();
                if stats.resident.iter().any(|&r| r > *budget) {
                    return false;
                }
                if stats.peak_node_resident > *budget {
                    return false;
                }
            }
            store.flush_replication();
            store.cache_stats().resident.iter().all(|&r| r <= *budget)
        },
    );
}

/// Backend equivalence: the same operation sequence produces an
/// identical observable trace — write/delete outcomes, byte-for-byte
/// reads, sizes, reclamation counts, and locality counters — on every
/// chunk backend (memory, file-per-chunk disk, packed segment log), and
/// each persistent store's data directory holds zero chunk files once
/// everything is deleted. (Single-threaded ops, no replication tags:
/// every counter is deterministic.)
#[test]
fn prop_backend_equivalence_mem_vs_disk_vs_seg() {
    use std::sync::atomic::Ordering;
    use woss::live::{
        chunk_crc, chunk_files_under, segment_files_under, BackendKind, CachePolicy, LiveStore,
        LiveTuning,
    };

    let case = std::sync::atomic::AtomicU64::new(0);
    forall_noshrink(
        "backend-equivalence",
        |rng: &mut Rng| {
            // Kept small: 256 cases × two file-backed stores is real
            // file I/O; the shapes (create/read/reclaim/delete
            // interleaving) matter, not the byte volume.
            (0..rng.range_usize(1, 12))
                .map(|_| {
                    (
                        rng.gen_range(5),           // 0-1 write, 2-3 read, 4 delete
                        rng.range_usize(0, 5),      // path index
                        rng.range_usize(0, 4),      // acting node
                        1 + rng.gen_range(300_000), // file size
                    )
                })
                .collect::<Vec<(u64, usize, usize, u64)>>()
        },
        |ops| {
            let case_id = case.fetch_add(1, Ordering::Relaxed);
            // Ample cache budget: under pressure a persistent store's
            // extra dirty (cache-only scratch) entries would shift
            // evictions relative to the memory store, making locality
            // counters legitimately diverge; pressure-path behaviour is
            // covered by the dedicated spill/eviction tests.
            let tuning = |backend: BackendKind, data_dir: Option<std::path::PathBuf>| LiveTuning {
                stripes: 4,
                repl_workers: 1,
                cache_bytes: Some(64 << 20),
                cache_policy: CachePolicy::HintAware,
                lifetime: true,
                backend,
                data_dir,
                fault: None,
                io_workers: 1,
                adaptive: false,
            };
            // Replay the ops on a store and record every observable
            // outcome: op success, read (len, crc), file_size after.
            let run_trace = |store: &LiveStore| -> Vec<(bool, Option<(usize, u64)>, Option<u64>)> {
                ops.iter()
                    .map(|&(op, pidx, node, size)| {
                        let path = format!("/e{pidx}");
                        let (done, read) = match op {
                            0 | 1 => {
                                let tags = if op == 0 {
                                    TagSet::from_pairs([
                                        ("DP", "local"),
                                        ("Lifetime", "scratch"),
                                        ("Consumers", "2"),
                                    ])
                                } else {
                                    TagSet::from_pairs([("DP", "local")])
                                };
                                let data = vec![(size % 251) as u8; size as usize];
                                (store.write_file(NodeId(node), &path, &data, &tags).is_ok(), None)
                            }
                            2 | 3 => match store.read_file(NodeId((node + 1) % 4), &path) {
                                Ok(bytes) => (true, Some((bytes.len(), chunk_crc(&bytes)))),
                                Err(_) => (false, None),
                            },
                            _ => (store.delete(&path).is_ok(), None),
                        };
                        (done, read, store.file_size(&path))
                    })
                    .collect()
            };

            let mem = LiveStore::woss_with(4, tuning(BackendKind::Memory, None));
            let mem_trace = run_trace(&mem);
            let mut ok = true;
            for kind in [BackendKind::Disk, BackendKind::Seg] {
                let dir = std::env::temp_dir().join(format!(
                    "woss-prop-equiv-{}-{}-{}",
                    kind.label(),
                    std::process::id(),
                    case_id
                ));
                let _ = std::fs::remove_dir_all(&dir);
                let store = LiveStore::woss_with(4, tuning(kind, Some(dir.clone())));
                // Observable behaviour converged: identical traces,
                // reclamation, and locality counters.
                ok &= run_trace(&store) == mem_trace;
                ok &= mem.cache_stats().files_reclaimed == store.cache_stats().files_reclaimed;
                ok &= mem.cache_stats().bytes_reclaimed == store.cache_stats().bytes_reclaimed;
                ok &= mem.local_reads.load(Ordering::Relaxed)
                    == store.local_reads.load(Ordering::Relaxed);
                ok &= mem.remote_reads.load(Ordering::Relaxed)
                    == store.remote_reads.load(Ordering::Relaxed);
                // Deleting every surviving file leaves zero chunk files
                // in the data directory and zero live backend bytes —
                // on seg the packed logs may remain, but hold nothing.
                for pidx in 0..5 {
                    let _ = store.delete(&format!("/e{pidx}"));
                }
                ok &= chunk_files_under(&dir) == 0;
                if kind == BackendKind::Seg {
                    ok &= segment_files_under(&dir) <= 4; // one active log per node
                } else {
                    ok &= segment_files_under(&dir) == 0;
                }
                ok &= store.backend_used_bytes().iter().sum::<u64>() == 0;
                drop(store);
                let _ = std::fs::remove_dir_all(&dir);
            }
            ok
        },
    );
}

/// Simulation determinism: identical seeds ⇒ identical results, across
/// every storage configuration.
#[test]
fn prop_simulation_deterministic() {
    forall_noshrink(
        "determinism",
        |rng: &mut Rng| (rng.next_u64(), rng.gen_range(3)),
        |&(seed, sys)| {
            use woss::bench::{execute, RunSpec, SystemKind};
            let system = match sys {
                0 => SystemKind::Nfs,
                1 => SystemKind::DssRam,
                _ => SystemKind::WossRam,
            };
            let hints = system == SystemKind::WossRam;
            let a = execute(
                &RunSpec::cluster(system, seed),
                &woss::workloads::reduce(8, 0.2, hints),
            );
            let b = execute(
                &RunSpec::cluster(system, seed),
                &woss::workloads::reduce(8, 0.2, hints),
            );
            a.makespan == b.makespan
        },
    );
}

/// Fault injection can fail or slow operations but never corrupt them:
/// a successful read returns exactly the bytes written, a failed write
/// leaves no trace (so `file_size` tracks the model), and once the
/// schedule is disabled and every file deleted, usage accounting drops
/// back to zero with no stray chunk files — on all three backends.
#[test]
fn prop_faulted_store_never_serves_wrong_bytes() {
    use std::sync::atomic::Ordering;
    use woss::live::{chunk_crc, chunk_files_under, BackendKind, FaultSpec, LiveStore, LiveTuning};

    let case = std::sync::atomic::AtomicU64::new(0);
    forall_noshrink(
        "fault-no-corruption",
        |rng: &mut Rng| {
            let spec = (
                rng.next_u64(),            // fault schedule seed
                rng.gen_range(80) as u16,  // put_error_permille
                rng.gen_range(50) as u16,  // torn_put_permille
                rng.gen_range(80) as u16,  // read_error_permille
            );
            // Small op lists: every case builds a disk-backed store, so
            // shape coverage (write/read/delete × fault mix) matters
            // more than volume.
            let ops = (0..rng.range_usize(2, 12))
                .map(|_| {
                    (
                        rng.gen_range(5),           // 0-1 write, 2-3 read, 4 delete
                        rng.range_usize(0, 4),      // path index
                        rng.range_usize(0, 4),      // acting node
                        1 + rng.gen_range(200_000), // file size
                    )
                })
                .collect::<Vec<(u64, usize, usize, u64)>>();
            (spec, ops)
        },
        |&((fseed, put_pm, torn_pm, read_pm), ref ops)| {
            let spec = FaultSpec {
                seed: fseed,
                put_error_permille: put_pm,
                torn_put_permille: torn_pm,
                read_error_permille: read_pm,
                ..FaultSpec::default()
            };
            let dir = std::env::temp_dir().join(format!(
                "woss-prop-fault-{}-{}",
                std::process::id(),
                case.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let mut ok = true;
            for backend in [BackendKind::Memory, BackendKind::Disk, BackendKind::Seg] {
                let store = LiveStore::woss_with(
                    4,
                    LiveTuning {
                        stripes: 4,
                        repl_workers: 1,
                        backend,
                        // Each persistent backend gets its own subtree
                        // so one sweep's debris can't leak into the
                        // next backend's accounting.
                        data_dir: backend.is_persistent().then(|| dir.join(backend.label())),
                        fault: Some(spec),
                        ..LiveTuning::default()
                    },
                );
                // Model of what was durably written: path -> (len, crc).
                let mut model: std::collections::BTreeMap<String, (usize, u64)> =
                    std::collections::BTreeMap::new();
                for &(op, pidx, node, size) in ops {
                    let path = format!("/f{pidx}");
                    match op {
                        0 | 1 => {
                            // No Replication tags here: optimistic copy
                            // jobs swallow injected put errors, which is
                            // churn-repair territory (scenario tests),
                            // not this invariant.
                            let tags = if op == 0 {
                                TagSet::from_pairs([("DP", "local")])
                            } else {
                                TagSet::from_pairs([("DP", "scatter 2")])
                            };
                            let data: Vec<u8> = (0..size as usize)
                                .map(|i| (i as u64).wrapping_mul(size | 1) as u8)
                                .collect();
                            if store.write_file(NodeId(node), &path, &data, &tags).is_ok() {
                                // Ok means a fresh write fully landed
                                // (AlreadyExists and injected put errors
                                // both surface as Err and change nothing).
                                model.insert(path.clone(), (data.len(), chunk_crc(&data)));
                            }
                        }
                        2 | 3 => {
                            if let Ok(bytes) = store.read_file(NodeId((node + 1) % 4), &path) {
                                // A read may fail (injected), but a
                                // successful one must match the model.
                                match model.get(&path) {
                                    Some(&(len, crc)) => {
                                        ok &= bytes.len() == len && chunk_crc(&bytes) == crc;
                                    }
                                    None => ok = false,
                                }
                            }
                        }
                        _ => {
                            let deleted = store.delete(&path).is_ok();
                            ok &= deleted == model.contains_key(&path);
                            model.remove(&path);
                        }
                    }
                    // Failed writes must unwind completely; successful
                    // ones (even torn) must register.
                    ok &= store.file_size(&path).is_some() == model.contains_key(&path);
                }
                // Disable the schedule: torn chunks heal, every file
                // must now read back exactly.
                store.fault_control().expect("faulted store").set_enabled(false);
                store.flush_replication();
                for (i, (path, &(len, crc))) in model.iter().enumerate() {
                    match store.read_file(NodeId(i % 4), path) {
                        Ok(bytes) => ok &= bytes.len() == len && chunk_crc(&bytes) == crc,
                        Err(_) => ok = false,
                    }
                }
                ok &= store.audit().clean();
                // Reclamation is exact: deleting everything returns the
                // backends to zero bytes, with no stray chunk files.
                for path in model.keys() {
                    ok &= store.delete(path).is_ok();
                }
                store.flush_replication();
                ok &= store.audit().clean();
                ok &= store.backend_used_bytes().iter().sum::<u64>() == 0;
                if let Some(root) = store.data_dir() {
                    ok &= chunk_files_under(root) == 0;
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
            ok
        },
    );
}
