//! Integration tests for the hint-driven lifetime & cache tier:
//! eviction-order properties (scratch before durable, pinned broadcast
//! never under the hint-aware policy), reclamation-after-last-read,
//! prefetch, and the NoSpace-under-cache-pressure regression.

use woss::dispatch::Registry;
use woss::hints::TagSet;
use woss::live::{CachePolicy, LiveStore, LiveTuning};
use woss::storage::NodeId;

const CHUNK: usize = 256 * 1024; // the live store's default chunk

fn cached(n_nodes: usize, cache_chunks: u64, lifetime: bool) -> LiveStore {
    LiveStore::woss_with(
        n_nodes,
        LiveTuning {
            cache_bytes: Some(cache_chunks * CHUNK as u64),
            cache_policy: CachePolicy::HintAware,
            lifetime,
            ..LiveTuning::default()
        },
    )
}

/// One-chunk payload.
fn chunk_data(fill: u8) -> Vec<u8> {
    vec![fill; CHUNK]
}

#[test]
fn scratch_evicts_before_durable_under_pressure() {
    let store = cached(3, 2, false);
    let durable = TagSet::from_pairs([("DP", "local")]);
    let scratch = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
    store
        .write_file(NodeId(0), "/durable", &chunk_data(1), &durable)
        .unwrap();
    store.write_file(NodeId(0), "/s1", &chunk_data(2), &scratch).unwrap();
    store.write_file(NodeId(0), "/s2", &chunk_data(3), &scratch).unwrap();

    // First touches from the consumer node: remote, filling its cache
    // (2-chunk budget) with the durable file and then /s1.
    store.read_file(NodeId(1), "/durable").unwrap();
    store.read_file(NodeId(1), "/s1").unwrap();
    // /s2 needs room: the scratch entry (/s1) must go, not the durable.
    store.read_file(NodeId(1), "/s2").unwrap();

    let before = store.cache_stats();
    let tier = store.backend_kind().label();
    assert_eq!(before.hits, 0, "all first touches");
    assert_eq!(before.evictions, 1, "/s1 made room for /s2");
    assert_eq!(
        store.get_xattr("/durable", "cache_state").unwrap(),
        format!("tier={tier};chunks=1;bytes={CHUNK};pinned=0;recovered=0"),
        "durable entry survived the pressure"
    );

    let remote_before = store.remote_reads.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(store.read_file(NodeId(1), "/durable").unwrap(), chunk_data(1));
    assert_eq!(store.cache_stats().hits, 1, "durable re-read is a cache hit");
    assert_eq!(
        store.remote_reads.load(std::sync::atomic::Ordering::Relaxed),
        remote_before,
        "no remote traffic for the cached durable file"
    );
    // The evicted scratch file reads correctly — remotely.
    assert_eq!(store.read_file(NodeId(1), "/s1").unwrap(), chunk_data(2));
    assert!(store.remote_reads.load(std::sync::atomic::Ordering::Relaxed) > remote_before);
}

#[test]
fn pinned_broadcast_never_evicted_until_fanout_completes() {
    let store = cached(4, 2, true);
    let bcast = TagSet::from_pairs([
        ("DP", "local"),
        ("Pattern", "broadcast"),
        ("Consumers", "2"),
    ]);
    store.write_file(NodeId(0), "/bcast", &chunk_data(9), &bcast).unwrap();
    let tier = store.backend_kind().label();
    assert_eq!(store.get_xattr("/bcast", "consumers_left").unwrap(), "2");

    // First declared consumer read caches the chunk pinned.
    store.read_file(NodeId(1), "/bcast").unwrap();
    assert_eq!(store.get_xattr("/bcast", "consumers_left").unwrap(), "1");
    assert_eq!(
        store.get_xattr("/bcast", "cache_state").unwrap(),
        format!("tier={tier};chunks=1;bytes={CHUNK};pinned=1;recovered=0")
    );

    // Heavy durable pressure through the same node's 2-chunk cache:
    // the pin must hold while a consumer is still outstanding.
    let durable = TagSet::from_pairs([("DP", "local")]);
    for i in 0..3 {
        let path = format!("/d{i}");
        store.write_file(NodeId(0), &path, &chunk_data(i), &durable).unwrap();
        store.read_file(NodeId(1), &path).unwrap();
    }
    assert_eq!(
        store.get_xattr("/bcast", "cache_state").unwrap(),
        format!("tier={tier};chunks=1;bytes={CHUNK};pinned=1;recovered=0"),
        "pinned broadcast entry survived durable churn"
    );

    // Last declared consumer: a cache hit, after which the fan-out is
    // complete and the pin is released (entry demoted to durable).
    let hits_before = store.cache_stats().hits;
    store.read_file(NodeId(1), "/bcast").unwrap();
    assert!(store.cache_stats().hits > hits_before, "served from the pin");
    assert_eq!(store.get_xattr("/bcast", "consumers_left").unwrap(), "0");
    assert_eq!(
        store.get_xattr("/bcast", "cache_state").unwrap(),
        format!("tier={tier};chunks=1;bytes={CHUNK};pinned=0;recovered=0"),
        "fan-out complete: unpinned, still resident"
    );

    // Now ordinary LRU applies: enough churn evicts it.
    for i in 0..2 {
        let path = format!("/e{i}");
        store.write_file(NodeId(0), &path, &chunk_data(i), &durable).unwrap();
        store.read_file(NodeId(1), &path).unwrap();
    }
    assert_eq!(
        store.get_xattr("/bcast", "cache_state").unwrap(),
        format!("tier={tier};chunks=0;bytes=0;pinned=0;recovered=0"),
        "unpinned entry ages out like any durable"
    );
    // The file itself is durable — still readable (remotely).
    assert_eq!(store.read_file(NodeId(2), "/bcast").unwrap(), chunk_data(9));
}

#[test]
fn scratch_reclaimed_after_last_declared_read() {
    let store = cached(3, 4, true);
    let tags = TagSet::from_pairs([
        ("DP", "local"),
        ("Lifetime", "scratch"),
        ("Consumers", "2"),
    ]);
    let data: Vec<u8> = (0..300_000u32).map(|i| (i % 251) as u8).collect();
    store.write_file(NodeId(0), "/tmp", &data, &tags).unwrap();
    assert_eq!(store.get_xattr("/tmp", "consumers_left").unwrap(), "2");

    assert_eq!(store.read_file(NodeId(1), "/tmp").unwrap(), data);
    assert_eq!(store.get_xattr("/tmp", "consumers_left").unwrap(), "1");
    assert_eq!(store.read_file(NodeId(2), "/tmp").unwrap(), data);

    // Last declared consumer has read: the file is dead — namespace,
    // chunks, capacity, and cached copies all reclaimed.
    assert!(store.read_file(NodeId(1), "/tmp").is_err());
    assert_eq!(store.file_size("/tmp"), None);
    assert_eq!(store.get_xattr("/tmp", "consumers_left"), None);
    let stats = store.cache_stats();
    assert_eq!(stats.files_reclaimed, 1);
    assert_eq!(stats.bytes_reclaimed, 300_000);
    assert_eq!(
        stats.resident.iter().sum::<u64>(),
        0,
        "cached copies purged with the file"
    );
    // The namespace slot is free again.
    store
        .write_file(NodeId(0), "/tmp", &chunk_data(7), &TagSet::new())
        .unwrap();
    assert_eq!(store.read_file(NodeId(0), "/tmp").unwrap(), chunk_data(7));
}

#[test]
fn lifetime_tags_inert_without_enforcement() {
    // Default store: no cache tier, no lifetime enforcement — the tags
    // are carried but change nothing (the pre-tier behaviour).
    let store = LiveStore::woss(3);
    let tags = TagSet::from_pairs([("Lifetime", "scratch"), ("Consumers", "1")]);
    store.write_file(NodeId(0), "/f", &chunk_data(4), &tags).unwrap();
    store.read_file(NodeId(1), "/f").unwrap();
    store.read_file(NodeId(1), "/f").unwrap();
    assert_eq!(store.file_size("/f"), Some(CHUNK as u64), "never reclaimed");
    assert_eq!(store.cache_stats().files_reclaimed, 0);
    assert_eq!(
        store.get_xattr("/f", "consumers_left").unwrap(),
        "1",
        "no decrement without enforcement"
    );
}

#[test]
fn prefetch_promotes_pipeline_handoff() {
    let store = cached(4, 8, false);
    let tags = TagSet::from_pairs([("DP", "local"), ("Pattern", "pipeline")]);
    let data = vec![0x5Au8; 4 * CHUNK];
    store.write_file(NodeId(0), "/pipe", &data, &tags).unwrap();

    let queued = store.prefetch(NodeId(1), "/pipe").unwrap();
    assert_eq!(queued, 4, "all four chunks promoted");
    store.flush_replication(); // promotion barrier
    assert_eq!(store.cache_stats().prefetched, 4);

    // The consumer's first read is now fully node-local.
    assert_eq!(store.read_file(NodeId(1), "/pipe").unwrap(), data);
    assert_eq!(store.local_reads.load(std::sync::atomic::Ordering::Relaxed), 4);
    assert_eq!(store.remote_reads.load(std::sync::atomic::Ordering::Relaxed), 0);

    // Re-prefetching a warm cache queues nothing.
    assert_eq!(store.prefetch(NodeId(1), "/pipe").unwrap(), 0);
    // Prefetching onto a holder is a no-op too.
    assert_eq!(store.prefetch(NodeId(0), "/pipe").unwrap(), 0);
}

#[test]
fn nospace_under_cache_pressure_rolls_back_cleanly() {
    // A capacity-bounded deployment with an active cache: placement
    // failures must roll back exactly as they do uncached, and cache
    // residency must stay within budget throughout.
    let budget = CHUNK as u64;
    let store = LiveStore::with_tuning(
        Registry::woss(),
        2,
        600_000,
        LiveTuning {
            cache_bytes: Some(budget),
            cache_policy: CachePolicy::HintAware,
            lifetime: true,
            ..LiveTuning::default()
        },
    );
    let data: Vec<u8> = (0..500_000u32).map(|i| (i % 199) as u8).collect();
    store.write_file(NodeId(0), "/a", &data, &TagSet::new()).unwrap();
    // Warm the cache from the other node.
    assert_eq!(store.read_file(NodeId(1), "/a").unwrap(), data);

    // 900 KB cannot fit the remaining pool capacity: NoSpace, with the
    // partial placement rolled back.
    let err = store
        .write_file(NodeId(0), "/big", &vec![1u8; 900_000], &TagSet::new())
        .unwrap_err();
    assert!(
        matches!(err, woss::storage::StorageError::NoSpace(_)),
        "expected NoSpace, got {err:?}"
    );
    assert!(store.file_size("/big").is_none());

    // The original file is untouched and the cache stayed bounded.
    assert_eq!(store.read_file(NodeId(1), "/a").unwrap(), data);
    let stats = store.cache_stats();
    assert!(stats.peak_node_resident <= budget);
    assert!(stats.resident.iter().all(|&r| r <= budget));

    // Rollback leaked no capacity: after deleting /a the pool takes a
    // 550 KB file again.
    store.delete("/a").unwrap();
    let data2: Vec<u8> = (0..550_000u32).map(|i| (i % 97) as u8).collect();
    store.write_file(NodeId(0), "/b", &data2, &TagSet::new()).unwrap();
    assert_eq!(store.read_file(NodeId(1), "/b").unwrap(), data2);
}
