//! Shared harness for the deterministic live-store test suites: one
//! seeded RNG per test, with the seed printed up front so any failing
//! schedule is replayable (`WOSS_TEST_SEED=<seed> cargo test ...`).

use woss::util::Rng;

/// Default seed when `WOSS_TEST_SEED` is unset — fixed, so plain CI
/// runs are bit-identical from run to run.
const DEFAULT_SEED: u64 = 0x5EED_0055;

/// One deterministic RNG for `test`, seeded from `WOSS_TEST_SEED` when
/// set (replaying a reported failure) or a fixed default. The seed is
/// printed immediately: a failing run's output always carries the
/// exact value needed to reproduce its schedule.
pub fn seeded_rng(test: &str) -> (u64, Rng) {
    let seed = std::env::var("WOSS_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    eprintln!("{test}: deterministic schedule from seed {seed} (replay: WOSS_TEST_SEED={seed})");
    (seed, Rng::new(seed))
}
