//! Concurrency tests for the striped live store: writer × reader
//! thread grids over collocated, scattered, and replicated files, with
//! byte-for-byte round-trip checks and the `flush_replication` barrier
//! asserting full replica counts. No kernel artifacts needed — this
//! exercises the storage layer only.
//!
//! Every test draws its payloads and orderings from one seeded RNG
//! (`common::seeded_rng`): the seed is printed up front and repeated
//! in assertion messages, so a failing interleaving is replayable with
//! `WOSS_TEST_SEED=<seed>`. There are no wall-clock sleeps — readers
//! retry until the work appears (the deadline below is an assertion
//! timeout, not a pause) and `flush_replication` is the only barrier.

mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};
use woss::hints::TagSet;
use woss::live::LiveStore;
use woss::storage::NodeId;

const WRITERS: usize = 4;
const READERS: usize = 4;
const FILES_PER_WRITER: usize = 6;

fn path_of(w: usize, f: usize) -> String {
    format!("/live/w{w}/f{f}")
}

/// Deterministic per-(writer, file) payload salt: a pure function of
/// the harness seed, so writer threads and reader threads regenerate
/// identical expected bytes without sharing an RNG stream.
fn salt_of(seed: u64, w: usize, f: usize) -> u64 {
    let mut rng = woss::util::Rng::new(seed ^ ((w as u64) << 32) ^ f as u64);
    rng.next_u64()
}

/// Deterministic, distinct payload per (writer, file); sizes straddle
/// several 256 KiB chunks so placement and replication fan out.
fn blob(w: usize, f: usize, salt: u64) -> Vec<u8> {
    let len = 300_000 + w * 60_000 + f * 17_000;
    let mult = salt | 1; // odd multiplier: every byte position varies
    (0..len)
        .map(|i| ((i as u64).wrapping_mul(mult) % 251) as u8)
        .collect()
}

/// Hints rotate through the paper's placement patterns; every third
/// file also replicates optimistically through the background pool.
fn tags_of(w: usize, f: usize) -> TagSet {
    match f % 3 {
        0 => TagSet::from_pairs([
            ("DP".to_string(), format!("collocation g{}", w % 2)),
            ("Replication".to_string(), "2".to_string()),
        ]),
        1 => TagSet::from_pairs([("DP", "scatter 2")]),
        _ => TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "optimistic")]),
    }
}

#[test]
fn writer_reader_grid_roundtrips_and_flush_replicates() {
    let (seed, mut rng) = common::seeded_rng("writer_reader_grid");
    let store = Arc::new(LiveStore::woss_tuned(8, 4, 2));

    // Each writer creates its files in a seed-shuffled order, so
    // different seeds exercise different create interleavings.
    let orders: Vec<Vec<usize>> = (0..WRITERS)
        .map(|_| {
            let mut order: Vec<usize> = (0..FILES_PER_WRITER).collect();
            rng.shuffle(&mut order);
            order
        })
        .collect();

    std::thread::scope(|scope| {
        // Writers: each creates its own files while readers are racing.
        for (w, order) in orders.iter().enumerate() {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                for &f in order {
                    let data = blob(w, f, salt_of(seed, w, f));
                    store
                        .write_file(NodeId(w % 8), &path_of(w, f), &data, &tags_of(w, f))
                        .expect("concurrent write");
                }
            });
        }
        // Readers: verify every file byte-for-byte as soon as its write
        // has returned; transient errors (file not created yet) retry.
        for r in 0..READERS {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                let deadline = Instant::now() + Duration::from_secs(60);
                let mut verified = 0usize;
                let mut done = vec![false; WRITERS * FILES_PER_WRITER];
                while verified < WRITERS * FILES_PER_WRITER {
                    assert!(
                        Instant::now() < deadline,
                        "reader {r} verified only {verified} files (WOSS_TEST_SEED={seed})"
                    );
                    for w in 0..WRITERS {
                        for f in 0..FILES_PER_WRITER {
                            let idx = w * FILES_PER_WRITER + f;
                            if done[idx] {
                                continue;
                            }
                            // A failing read is legal only for a file
                            // whose create is still racing; it retries
                            // until the deadline catches real bugs.
                            if let Ok(back) = store.read_file(NodeId((r + w) % 8), &path_of(w, f))
                            {
                                assert_eq!(
                                    back,
                                    blob(w, f, salt_of(seed, w, f)),
                                    "bytes corrupted for writer {w} file {f} \
                                     (WOSS_TEST_SEED={seed})"
                                );
                                done[idx] = true;
                                verified += 1;
                            }
                        }
                    }
                    std::thread::yield_now();
                }
            });
        }
    });

    // Every write returned, so every file must now read back exactly —
    // replicas may still be draining, reads fall back to the primary.
    for w in 0..WRITERS {
        for f in 0..FILES_PER_WRITER {
            let back = store.read_file(NodeId(7), &path_of(w, f)).unwrap();
            assert_eq!(back, blob(w, f, salt_of(seed, w, f)));
        }
    }

    // The determinism barrier: after the flush, every file holds its
    // full replica count on every assigned holder.
    store.flush_replication();
    assert_eq!(store.pending_replication(), 0);
    for w in 0..WRITERS {
        for f in 0..FILES_PER_WRITER {
            assert!(
                store.fully_replicated(&path_of(w, f)).unwrap(),
                "writer {w} file {f} missing replicas after flush (WOSS_TEST_SEED={seed})"
            );
        }
    }
    let expected: u64 = (WRITERS * FILES_PER_WRITER * 300_000) as u64;
    assert!(store.bytes_written.load(Ordering::Relaxed) >= expected);
}

#[test]
fn collocated_files_share_an_anchor_across_stripes() {
    // Collocation anchors are global: files of one group land together
    // no matter which lock stripe their paths hash to — even when the
    // writes race each other.
    let (seed, _rng) = common::seeded_rng("collocated_anchor");
    let store = Arc::new(LiveStore::woss_tuned(6, 4, 1));
    std::thread::scope(|scope| {
        for w in 0..4usize {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                let tags = TagSet::from_pairs([("DP", "collocation shared")]);
                store
                    .write_file(
                        NodeId(w),
                        &format!("/g/{w}"),
                        &blob(w, 0, salt_of(seed, w, 0)),
                        &tags,
                    )
                    .unwrap();
            });
        }
    });
    let mut anchors = Vec::new();
    for w in 0..4usize {
        let holders = store.locations(&format!("/g/{w}"));
        assert_eq!(holders.len(), 1, "collocated file on one node");
        anchors.push(holders[0]);
    }
    anchors.dedup();
    assert_eq!(
        anchors.len(),
        1,
        "one shared anchor: {anchors:?} (WOSS_TEST_SEED={seed})"
    );
}

#[test]
fn single_stripe_store_survives_the_same_grid() {
    // stripes=1 is the previous single-lock behaviour; the concurrent
    // grid must still round-trip (just without metadata parallelism).
    let (seed, _rng) = common::seeded_rng("single_stripe_grid");
    let store = Arc::new(LiveStore::woss_tuned(4, 1, 1));
    std::thread::scope(|scope| {
        for w in 0..4usize {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                for f in 0..3usize {
                    let data = blob(w, f, salt_of(seed, w, f));
                    store
                        .write_file(NodeId(w), &path_of(w, f), &data, &tags_of(w, f))
                        .unwrap();
                    let back = store.read_file(NodeId((w + 1) % 4), &path_of(w, f)).unwrap();
                    assert_eq!(back, data, "WOSS_TEST_SEED={seed}");
                }
            });
        }
    });
    store.flush_replication();
    for w in 0..4usize {
        for f in 0..3usize {
            assert!(store.fully_replicated(&path_of(w, f)).unwrap());
        }
    }
}
