//! Crash consistency & restart: a persistent store killed without a
//! clean shutdown — dropped after `flush_replication()`, which is what
//! a `kill -9` looks like to the file system — must reopen on the same
//! `--data-dir` and serve every fully-replicated durable file
//! byte-identical. Scratch files must never resurrect, a clean
//! shutdown must restore the namespace *as it was* (post-create tags
//! included), and the `recovered=` bottom-up field must tell the
//! scheduler which files made it. The kill-and-reopen sweep runs on
//! both persistent backends (`disk` and `seg`), and the seg-specific
//! tests plant real crash debris — torn segment tails, orphan `.tmp`
//! and unlisted segments, checksum-corrupt records, compaction cut
//! short — and demand salvage without resurrection. These tests run
//! under every `LIVE_BACKEND` matrix leg but exercise explicit
//! tunings, so the guarantees hold regardless of the env default.

mod common;

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use woss::dispatch::Registry;
use woss::hints::TagSet;
use woss::live::{chunk_files_under, segment_files_under, BackendKind, LiveStore, LiveTuning};
use woss::storage::types::NodeId;

/// A private temp dir per test, honoring `WOSS_DATA_DIR` so the CI
/// stray-file audit covers whatever these tests leave behind.
fn test_dir(tag: &str) -> PathBuf {
    let base = std::env::var_os("WOSS_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    let dir = base.join(format!("woss-recovery-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn backend_tuning(kind: BackendKind, dir: &Path) -> LiveTuning {
    LiveTuning {
        backend: kind,
        data_dir: Some(dir.to_path_buf()),
        ..LiveTuning::default()
    }
}

fn disk_tuning(dir: &Path) -> LiveTuning {
    backend_tuning(BackendKind::Disk, dir)
}

fn woss_on(kind: BackendKind, dir: &Path, nodes: usize) -> LiveStore {
    LiveStore::with_tuning(Registry::woss(), nodes, u64::MAX / 2, backend_tuning(kind, dir))
}

fn woss_disk(dir: &Path, nodes: usize) -> LiveStore {
    woss_on(BackendKind::Disk, dir, nodes)
}

/// The segment files of one node, in `segments.meta` replay order —
/// the last entry is the active (append) segment.
fn node_segments(node_dir: &Path) -> Vec<PathBuf> {
    std::fs::read_to_string(node_dir.join("segments.meta"))
        .unwrap()
        .lines()
        .map(|l| node_dir.join(l.trim()))
        .collect()
}

/// Deterministic per-file payload.
fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mult = seed.wrapping_mul(2).wrapping_add(31);
    (0..len)
        .map(|i| ((i as u64).wrapping_mul(mult).wrapping_add(seed)) as u8)
        .collect()
}

#[test]
fn crash_reopen_serves_durable_files_byte_identical() {
    let dir = test_dir("crash");
    let mut expected: Vec<(String, Vec<u8>)> = Vec::new();
    {
        let store = woss_disk(&dir, 4);
        // A mix of shapes: replicated, single-copy local, multi-chunk,
        // custom block size, empty.
        let cases: [(&str, usize, TagSet); 5] = [
            ("/db/replicated", 700_000, TagSet::from_pairs([("Replication", "3")])),
            ("/w/local", 300_000, TagSet::from_pairs([("DP", "local")])),
            ("/w/multichunk", 900_000, TagSet::new()),
            (
                "/w/smallblocks",
                200_000,
                TagSet::from_pairs([("BlockSize", "64K")]),
            ),
            ("/w/empty", 0, TagSet::new()),
        ];
        for (i, (path, len, tags)) in cases.into_iter().enumerate() {
            let data = payload(i as u64 + 1, len);
            store.write_file(NodeId(i % 4), path, &data, &tags).unwrap();
            expected.push((path.to_string(), data));
        }
        store.flush_replication();
        for (path, _) in &expected {
            assert!(store.fully_replicated(path).unwrap());
        }
        // Killed: dropped with NO clean shutdown.
    }

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().expect("reopen reports").clone();
    assert!(!recovery.clean, "no CLEAN marker: this is the crash path");
    assert_eq!(recovery.files_recovered, expected.len());
    assert_eq!(recovery.files_dropped, 0);
    for (i, (path, data)) in expected.iter().enumerate() {
        // Byte-identical from several vantage points (locality paths
        // differ; content must not).
        for reader in 0..4 {
            assert_eq!(
                &store.read_file(NodeId(reader), path).unwrap(),
                data,
                "{path} from n{reader}"
            );
        }
        assert!(store.was_recovered(path), "{path} recovered");
        let state = store.get_xattr(path, "cache_state").unwrap();
        assert!(
            state.ends_with(";recovered=1"),
            "bottom-up recovered flag on {path}: {state}"
        );
        assert!(store.fully_replicated(path).unwrap(), "case {i} replicas back");
    }
    // The pool summary carries the store-wide count.
    let status = store.get_xattr("/db/replicated", "system_status").unwrap();
    assert!(
        status.contains(&format!("recovered={} ", expected.len())),
        "system_status reports the recovered count: {status}"
    );
    assert!(
        status.contains("under_replicated=0"),
        "no churn: nothing under-replicated: {status}"
    );
    assert!(
        status.ends_with("io_queue=0"),
        "idle data path: empty I/O queue: {status}"
    );

    // A file created *after* the reopen is not "recovered".
    store
        .write_file(NodeId(0), "/new", &payload(99, 10_000), &TagSet::new())
        .unwrap();
    assert!(!store.was_recovered("/new"));
    assert!(store
        .get_xattr("/new", "cache_state")
        .unwrap()
        .ends_with(";recovered=0"));

    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn clean_shutdown_snapshot_restores_post_create_tags() {
    let dir = test_dir("clean");
    {
        let store = woss_disk(&dir, 3);
        let data = payload(7, 400_000);
        store
            .write_file(NodeId(1), "/f", &data, &TagSet::from_pairs([("DP", "local")]))
            .unwrap();
        // Mutate the namespace after the create: the journal only has
        // the create-time record, so only the snapshot carries this.
        store.set_xattr("/f", "stage", "calibrated");
        store.shutdown();
    }
    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert!(recovery.clean, "CLEAN marker honored: snapshot path");
    assert_eq!(recovery.files_recovered, 1);
    assert_eq!(
        store.get_xattr("/f", "stage").as_deref(),
        Some("calibrated"),
        "clean shutdown preserves post-create tag mutations"
    );
    assert_eq!(store.read_file(NodeId(0), "/f").unwrap(), payload(7, 400_000));
    // Writing anything invalidates the marker: the *next* restart
    // without a shutdown must fall back to journal salvage, not trust
    // a stale snapshot.
    store
        .write_file(NodeId(0), "/g", &payload(8, 100_000), &TagSet::new())
        .unwrap();
    drop(store); // crash
    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    assert!(
        !store.recovery_report().unwrap().clean,
        "post-shutdown writes invalidated the snapshot"
    );
    assert_eq!(store.read_file(NodeId(0), "/g").unwrap(), payload(8, 100_000));
    assert_eq!(store.read_file(NodeId(0), "/f").unwrap(), payload(7, 400_000));
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scratch_and_deleted_files_never_resurrect() {
    let dir = test_dir("scratch");
    {
        let store = LiveStore::with_tuning(
            Registry::woss(),
            3,
            u64::MAX / 2,
            LiveTuning {
                cache_bytes: Some(64 << 20),
                lifetime: true,
                ..disk_tuning(&dir)
            },
        );
        store
            .write_file(
                NodeId(0),
                "/durable",
                &payload(1, 500_000),
                &TagSet::new(),
            )
            .unwrap();
        // Scratch both ways: spill-skipped (dirty cache-only) and
        // plainly tagged without a consumer count.
        store
            .write_file(
                NodeId(0),
                "/scratch/skip",
                &payload(2, 300_000),
                &TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch"), ("Consumers", "2")]),
            )
            .unwrap();
        store
            .write_file(
                NodeId(1),
                "/scratch/plain",
                &payload(3, 300_000),
                &TagSet::from_pairs([("Lifetime", "scratch")]),
            )
            .unwrap();
        store
            .write_file(NodeId(2), "/deleted", &payload(4, 200_000), &TagSet::new())
            .unwrap();
        store.delete("/deleted").unwrap();
        store.flush_replication();
    } // crash

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert_eq!(recovery.files_recovered, 1, "only /durable survives");
    assert!(recovery.scratch_discarded >= 1, "scratch dropped on principle");
    assert_eq!(store.file_size("/scratch/skip"), None);
    assert_eq!(store.file_size("/scratch/plain"), None);
    assert_eq!(store.file_size("/deleted"), None);
    assert_eq!(store.read_file(NodeId(0), "/durable").unwrap(), payload(1, 500_000));
    // No dead file's chunk survives on disk: everything in the data
    // dir is accounted to the one recovered file.
    let indexed: usize = store.backend_chunk_counts().iter().sum();
    assert_eq!(chunk_files_under(&dir), indexed, "no unclaimed chunk files");
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill-and-reopen property sweep, run on BOTH persistent backends:
/// seeded rounds of mixed durable/scratch/deleted traffic, killed
/// mid-lifecycle (after the replication barrier), reopened, and checked
/// invariant by invariant: every surviving durable file byte-identical,
/// every dead path absent, the on-disk chunk population exactly the
/// recovered index (per-chunk files on `disk`, packed logs and zero
/// chunk files on `seg`).
#[test]
fn prop_kill_and_reopen_roundtrips() {
    // One harness RNG seeds every round: a failing round is replayed
    // by re-running with the printed WOSS_TEST_SEED. Both backends see
    // the same per-round traffic, so a divergence is a backend bug.
    let (base, mut harness) = common::seeded_rng("prop_kill_and_reopen_roundtrips");
    for round in 0..5u64 {
        let seed = harness.next_u64();
        for kind in [BackendKind::Disk, BackendKind::Seg] {
            let dir = test_dir(&format!("prop{round}-{}", kind.label()));
            let mut live: Vec<(String, Vec<u8>)> = Vec::new();
            let mut dead: Vec<String> = Vec::new();
            {
                let store = woss_on(kind, &dir, 4);
                let mut rng = woss::util::Rng::new(seed);
                for f in 0..12u64 {
                    let path = format!("/p{f}");
                    let len = 50_000 + rng.gen_range(500_000) as usize;
                    let data = payload(rng.next_u64(), len);
                    let tags = match rng.gen_range(4) {
                        0 => TagSet::from_pairs([("Replication", "2")]),
                        1 => TagSet::from_pairs([("DP", "local")]),
                        2 => TagSet::from_pairs([("Lifetime", "scratch")]),
                        _ => TagSet::new(),
                    };
                    let scratch = tags.get("Lifetime").is_some();
                    store
                        .write_file(NodeId(rng.gen_range(4) as usize), &path, &data, &tags)
                        .unwrap();
                    if rng.gen_range(5) == 0 {
                        store.delete(&path).unwrap();
                        dead.push(path);
                    } else if scratch {
                        dead.push(path);
                    } else {
                        live.push((path, data));
                    }
                }
                store.flush_replication();
                for (path, _) in &live {
                    assert!(store.fully_replicated(path).unwrap());
                }
            } // killed

            let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
            let recovery = store.recovery_report().unwrap().clone();
            assert_eq!(
                recovery.files_recovered,
                live.len(),
                "round {round} on {kind:?} (WOSS_TEST_SEED={base})"
            );
            for (path, data) in &live {
                assert_eq!(
                    &store.read_file(NodeId(0), path).unwrap(),
                    data,
                    "round {round} {path} on {kind:?} (WOSS_TEST_SEED={base})"
                );
            }
            for path in &dead {
                assert!(
                    store.read_file(NodeId(0), path).is_err(),
                    "round {round}: {path} must stay dead on {kind:?} (WOSS_TEST_SEED={base})"
                );
            }
            match kind {
                BackendKind::Seg => {
                    // Packed layout: zero per-chunk files ever, and the
                    // recovered population lives in O(segments) logs.
                    assert_eq!(
                        chunk_files_under(&dir),
                        0,
                        "round {round}: seg never writes chunk files (WOSS_TEST_SEED={base})"
                    );
                    assert!(
                        segment_files_under(&dir) > 0,
                        "round {round}: recovered chunks live in segment logs"
                    );
                }
                _ => {
                    let indexed: usize = store.backend_chunk_counts().iter().sum();
                    assert_eq!(
                        chunk_files_under(&dir),
                        indexed,
                        "round {round}: orphans swept (WOSS_TEST_SEED={base})"
                    );
                }
            }
            // The reopened store is a working store: fresh writes and
            // reads proceed, ids never collide with recovered files.
            store
                .write_file(NodeId(0), "/fresh", &payload(1234, 300_000), &TagSet::new())
                .unwrap();
            assert_eq!(store.read_file(NodeId(1), "/fresh").unwrap(), payload(1234, 300_000));
            for (path, data) in &live {
                assert_eq!(&store.read_file(NodeId(2), path).unwrap(), data);
            }
            drop(store);
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
}

#[test]
fn fresh_store_refuses_populated_data_dir() {
    let dir = test_dir("refuse");
    {
        let store = woss_disk(&dir, 2);
        store
            .write_file(NodeId(0), "/f", &payload(1, 100_000), &TagSet::new())
            .unwrap();
        store.flush_replication();
    }
    // The old bug: a fresh store over the same dir silently orphaned
    // every durable file. Now it refuses and names the fix.
    let err = LiveStore::try_with_tuning(Registry::woss(), 2, u64::MAX / 2, disk_tuning(&dir))
        .err()
        .expect("fresh open over a previous store must fail");
    assert!(
        err.to_string().contains("reopen"),
        "error points at recovery: {err}"
    );
    // And the recovery path it names works.
    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    assert_eq!(store.read_file(NodeId(1), "/f").unwrap(), payload(1, 100_000));
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_primary_fails_over_and_counts_read_errors() {
    let dir = test_dir("corrupt");
    let store = woss_disk(&dir, 3);
    let data = payload(5, 400_000);
    store
        .write_file(
            NodeId(0),
            "/db",
            &data,
            // DP=local pins every primary to node0, so the damage below
            // covers every chunk and each read must fail over.
            &TagSet::from_pairs([("DP", "local"), ("Replication", "2")]),
        )
        .unwrap();
    store.flush_replication();
    // Flip bytes in every chunk file under node0 (same length, so only
    // the checksum can notice). read_file must fail over to a replica
    // and the faults must be counted, not dissolved into remote noise.
    let node0 = dir.join("node0");
    let mut damaged = 0;
    for entry in std::fs::read_dir(&node0).unwrap().flatten() {
        let p = entry.path();
        if p.extension().is_some_and(|e| e == "chunk") {
            let len = std::fs::metadata(&p).unwrap().len() as usize;
            std::fs::write(&p, vec![0xAAu8; len]).unwrap();
            damaged += 1;
        }
    }
    assert!(damaged > 0, "node0 held chunks to damage");
    assert_eq!(
        store.read_file(NodeId(0), "/db").unwrap(),
        data,
        "reads fail over to intact replicas"
    );
    let stats = store.cache_stats();
    assert!(
        stats.read_errors >= damaged as u64,
        "disk faults surfaced as read_errors: {} < {damaged}",
        stats.read_errors
    );
    assert_eq!(
        store.remote_reads.load(Ordering::Relaxed) as usize, damaged,
        "each damaged chunk was served remotely"
    );
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Regression: a duplicated holder entry (a damaged or hand-edited
/// journal can smuggle one through recovery — natural placement never
/// produces one) must be probed ONCE by the read failover loop.
/// Pre-fix, the loop walked the raw holder list, so a corrupt
/// duplicated source was probed per entry and `read_errors` counted
/// the same fault twice.
#[test]
fn duplicated_holder_is_probed_once_after_corruption() {
    let dir = test_dir("dupholder");
    let data = payload(6, 200_000); // a single 256 KiB chunk
    {
        let store = woss_disk(&dir, 3);
        store
            .write_file(
                NodeId(0),
                "/dup",
                &data,
                // DP=local pins the primary to node0; one replica lands
                // on node1 or node2.
                &TagSet::from_pairs([("DP", "local"), ("Replication", "2")]),
            )
            .unwrap();
        store.flush_replication();
    } // crash

    // Rewrite the journal's create record so the chunk's holder list
    // duplicates the primary ("0,r" -> "0,0,r"). Reopen keeps every
    // holder entry that verifies bottom-up — duplicates included.
    let log = dir.join("namespace.log");
    let text = std::fs::read_to_string(&log).unwrap();
    let patched: Vec<String> = text
        .lines()
        .map(|line| {
            let mut fields: Vec<String> = line.split('\t').map(str::to_string).collect();
            if fields.first().is_some_and(|f| f == "create") {
                let holders = fields.last().unwrap().clone();
                let primary = holders.split(',').next().unwrap().to_string();
                *fields.last_mut().unwrap() = format!("{primary},{holders}");
            }
            fields.join("\t")
        })
        .collect();
    std::fs::write(&log, patched.join("\n") + "\n").unwrap();

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    // The compacted journal proves the duplicate survived recovery
    // (the namespace's `holders()` view dedupes, so check bottom-up).
    let compacted = std::fs::read_to_string(&log).unwrap();
    assert!(
        compacted.lines().any(|l| {
            l.split('\t').last().is_some_and(|h| {
                let ids: Vec<&str> = h.split(',').collect();
                ids.len() == 3 && ids[0] == ids[1]
            })
        }),
        "duplicated holder survived reopen: {compacted:?}"
    );
    let holders = store.locations("/dup");
    let reader = (0..3)
        .map(NodeId)
        .find(|n| !holders.contains(n))
        .expect("one node holds nothing");

    // Corrupt every chunk file on node0, the duplicated holder (same
    // length, so only the checksum can notice).
    let node0 = dir.join("node0");
    let mut damaged = 0u64;
    for entry in std::fs::read_dir(&node0).unwrap().flatten() {
        let p = entry.path();
        if p.extension().is_some_and(|e| e == "chunk") {
            let len = std::fs::metadata(&p).unwrap().len() as usize;
            std::fs::write(&p, vec![0xAAu8; len]).unwrap();
            damaged += 1;
        }
    }
    assert!(damaged > 0, "node0 held chunks to damage");

    assert_eq!(
        store.read_file(reader, "/dup").unwrap(),
        data,
        "read fails over past the corrupt duplicated holder"
    );
    assert_eq!(
        store.cache_stats().read_errors,
        damaged,
        "the corrupt duplicated holder is probed exactly once per chunk"
    );
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Total bytes across one node's listed segment files.
fn seg_bytes(node_dir: &Path) -> u64 {
    node_segments(node_dir)
        .iter()
        .map(|p| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
        .sum()
}

/// A torn segment tail — the record a crash cut mid-append — is
/// discarded on reopen, the valid prefix survives byte-identical, and
/// the truncation is durable: a second crash-reopen sees no debris.
#[test]
fn seg_crash_reopen_discards_torn_tail_and_serves_files() {
    let dir = test_dir("seg-torn");
    let mut expected: Vec<(String, Vec<u8>)> = Vec::new();
    {
        let store = woss_on(BackendKind::Seg, &dir, 3);
        for (i, len) in [400_000usize, 150_000, 0].into_iter().enumerate() {
            let path = format!("/t{i}");
            let data = payload(i as u64 + 40, len);
            store
                .write_file(NodeId(0), &path, &data, &TagSet::from_pairs([("DP", "local")]))
                .unwrap();
            expected.push((path, data));
        }
        store.flush_replication();
    } // killed

    // Append a half-written record to node0's active segment: a valid
    // header whose claimed payload runs past end-of-file.
    let active = node_segments(&dir.join("node0")).pop().expect("node0 has segments");
    let mut torn = vec![1u8]; // SEG_PUT
    for v in [9u64, 9, 1 << 20, 0] {
        torn.extend_from_slice(&v.to_le_bytes());
    }
    torn.extend_from_slice(b"cut");
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&active).unwrap();
        f.write_all(&torn).unwrap();
    }

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert_eq!(recovery.files_recovered, expected.len());
    assert!(recovery.chunks_dropped >= 1, "the torn record was counted and dropped");
    for (path, data) in &expected {
        assert_eq!(&store.read_file(NodeId(1), path).unwrap(), data, "{path}");
        assert!(store.was_recovered(path));
    }
    assert!(
        store.read_file(NodeId(0), "/t9").is_err(),
        "the torn record resurrects nothing"
    );
    drop(store); // crash again, no new debris

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    assert_eq!(
        store.recovery_report().unwrap().chunks_dropped,
        0,
        "the first reopen truncated the torn tail durably"
    );
    for (path, data) in &expected {
        assert_eq!(&store.read_file(NodeId(2), path).unwrap(), data);
    }
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Orphan segments — files a crashed compaction wrote but never
/// published in `segments.meta`, and half-renamed `.tmp` segments — are
/// swept on reopen and can never resurrect data: only meta-listed
/// segments are replayed.
#[test]
fn seg_orphan_and_tmp_segments_swept_on_reopen() {
    let dir = test_dir("seg-orphan");
    let keep = payload(50, 300_000);
    {
        let store = woss_on(BackendKind::Seg, &dir, 2);
        store
            .write_file(NodeId(0), "/keep", &keep, &TagSet::from_pairs([("DP", "local")]))
            .unwrap();
        store.flush_replication();
    } // killed mid-compaction, as far as reopen can tell

    // Debris a compaction crash leaves behind: an unlisted rewritten
    // segment and a half-renamed temp file.
    let node0 = dir.join("node0");
    let orphan = node0.join("seg-99.log");
    let tmp = node0.join("seg-98.log.tmp");
    std::fs::write(&orphan, b"stale rewritten segment from a dead compactor").unwrap();
    std::fs::write(&tmp, b"half-renamed").unwrap();

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert_eq!(recovery.files_recovered, 1);
    assert!(recovery.chunks_dropped >= 1, "the orphan segment was counted");
    assert!(!orphan.exists(), "unlisted segment swept");
    assert!(!tmp.exists(), "tmp segment swept");
    assert_eq!(store.read_file(NodeId(1), "/keep").unwrap(), keep);
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A checksum-corrupt record (bit rot or a mangled sector inside an
/// otherwise healthy segment) is dropped on reopen; the file survives
/// through its replica on another node and reads byte-identical.
#[test]
fn seg_checksum_corrupt_record_dropped_replica_serves() {
    let dir = test_dir("seg-corrupt");
    let data = payload(60, 200_000); // one chunk
    {
        let store = woss_on(BackendKind::Seg, &dir, 3);
        store
            .write_file(
                NodeId(0),
                "/db",
                &data,
                // DP=local pins the primary to node0; the replica lands
                // on node1 or node2 and must carry the recovery.
                &TagSet::from_pairs([("DP", "local"), ("Replication", "2")]),
            )
            .unwrap();
        store.flush_replication();
    } // killed

    // Flip one payload byte inside node0's first record (offset past
    // the 33-byte header). Same length: only the checksum can notice.
    let first = node_segments(&dir.join("node0"))
        .into_iter()
        .next()
        .expect("node0 has segments");
    let mut bytes = std::fs::read(&first).unwrap();
    bytes[33 + 10] ^= 0xFF;
    std::fs::write(&first, &bytes).unwrap();

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert_eq!(recovery.files_recovered, 1, "the replica carried the file");
    assert!(recovery.chunks_dropped >= 1, "the corrupt record was dropped");
    for reader in 0..3 {
        assert_eq!(
            store.read_file(NodeId(reader), "/db").unwrap(),
            data,
            "byte-identical from n{reader}"
        );
    }
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Online compaction followed by a crash: lifetime reclamation of
/// scratch files triggers segment compaction (the dead bytes cross the
/// threshold), the node's on-disk footprint shrinks, and a reopen after
/// the crash serves every durable survivor byte-identical — with none
/// of the reclaimed scratch resurrected from pre-compaction segments.
#[test]
fn seg_compaction_then_crash_recovers_survivors_only() {
    let dir = test_dir("seg-compact");
    let keep: Vec<Vec<u8>> = (0..2).map(|i| payload(70 + i, 600_000)).collect();
    {
        let store = LiveStore::with_tuning(
            Registry::woss(),
            2,
            u64::MAX / 2,
            LiveTuning {
                lifetime: true,
                ..backend_tuning(BackendKind::Seg, &dir)
            },
        );
        for (i, data) in keep.iter().enumerate() {
            store
                .write_file(
                    NodeId(0),
                    &format!("/keep{i}"),
                    data,
                    &TagSet::from_pairs([("DP", "local")]),
                )
                .unwrap();
        }
        // ~5.4 MB of scratch on node0 — past the 4 MB dead-bytes
        // threshold once consumed, so reclamation must compact.
        for f in 0..9 {
            store
                .write_file(
                    NodeId(0),
                    &format!("/tmp{f}"),
                    &payload(80 + f, 600_000),
                    &TagSet::from_pairs([
                        ("DP", "local"),
                        ("Lifetime", "scratch"),
                        ("Consumers", "1"),
                    ]),
                )
                .unwrap();
        }
        for f in 0..9 {
            store.read_file(NodeId(1), &format!("/tmp{f}")).unwrap();
        }
        store.flush_replication();
        assert!(
            store.cache_stats().files_reclaimed >= 9,
            "every consumed scratch file was reclaimed"
        );
        assert_eq!(store.file_size("/tmp0"), None);
        assert!(
            seg_bytes(&dir.join("node0")) < 4_000_000,
            "compaction shrank node0 below its ~6.6 MB of raw appends"
        );
    } // killed right after the compaction

    let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
    let recovery = store.recovery_report().unwrap().clone();
    assert_eq!(recovery.files_recovered, 2, "only the durable files survive");
    for (i, data) in keep.iter().enumerate() {
        assert_eq!(&store.read_file(NodeId(1), &format!("/keep{i}")).unwrap(), data);
    }
    for f in 0..9 {
        assert!(
            store.read_file(NodeId(0), &format!("/tmp{f}")).is_err(),
            "/tmp{f} stays reclaimed — compaction left no resurrectable copy"
        );
    }
    assert_eq!(chunk_files_under(&dir), 0);
    assert!(
        segment_files_under(&dir) <= 4,
        "the compacted node holds O(segments) files, not O(chunks)"
    );
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}
