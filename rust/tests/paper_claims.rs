//! Integration tests asserting the paper's headline claims hold on the
//! simulated testbed — the reproduction's acceptance suite.

use woss::bench::{execute, RunSpec, SystemKind};
use woss::workloads::{self, Blast, ModFtDock, Montage};

fn wf_time(sys: SystemKind, hints: bool, seed: u64) -> f64 {
    execute(
        &RunSpec::cluster(sys, seed),
        &workloads::pipeline(19, 1.0, hints),
    )
    .workflow_span()
}

#[test]
fn fig5_pipeline_ordering_and_factors() {
    let nfs = wf_time(SystemKind::Nfs, false, 1);
    let dss_ram = wf_time(SystemKind::DssRam, false, 1);
    let woss_ram = wf_time(SystemKind::WossRam, true, 1);
    let local = execute(
        &RunSpec::cluster(SystemKind::LocalRam, 1),
        &workloads::pipeline(19, 1.0, false),
    )
    .workflow_span();

    assert!(woss_ram < dss_ram && dss_ram < nfs, "ordering");
    assert!(nfs / woss_ram > 5.0, "order-of-magnitude vs NFS (paper ~10x)");
    assert!(dss_ram / woss_ram > 1.5, "sizeable gain vs DSS (paper ~2x)");
    assert!(
        (woss_ram - local).abs() / local < 0.2,
        "WOSS ≈ node-local optimum: {woss_ram:.2} vs {local:.2}"
    );
}

#[test]
fn fig5_disk_variants_slower_than_ram() {
    assert!(wf_time(SystemKind::DssDisk, false, 2) > wf_time(SystemKind::DssRam, false, 2));
    assert!(wf_time(SystemKind::WossDisk, true, 2) > wf_time(SystemKind::WossRam, true, 2));
}

#[test]
fn fig6_broadcast_replication_has_interior_optimum() {
    // Average over seeds: the effect is a few percent and jittered.
    let run = |rep: u32| -> f64 {
        (0..3)
            .map(|s| {
                execute(
                    &RunSpec::cluster(SystemKind::WossRam, 3 + s),
                    &workloads::broadcast(19, rep, 1.0, true),
                )
                .workflow_span()
            })
            .sum::<f64>()
            / 3.0
    };
    // The paper's fig6 sweeps the factor and finds the best performance
    // at 8 replicas, with over-replication costing more than it gains.
    let r2 = run(2);
    let r8 = run(8);
    let r16 = run(16);
    assert!(r8 < r2, "more replicas help up to the optimum: r8 {r8:.2} vs r2 {r2:.2}");
    assert!(
        r16 > r8,
        "over-replication must cost more than it gains (paper: past ~8): r16 {r16:.2} vs r8 {r8:.2}"
    );
}

#[test]
fn fig7_reduce_ordering() {
    let run = |sys: SystemKind, hints: bool| {
        execute(
            &RunSpec::cluster(sys, 4),
            &workloads::reduce(19, 1.0, hints),
        )
        .workflow_span()
    };
    let nfs = run(SystemKind::Nfs, false);
    let dss = run(SystemKind::DssRam, false);
    let woss = run(SystemKind::WossRam, true);
    assert!(woss < dss, "collocation must beat striping: {woss:.1} vs {dss:.1}");
    assert!(dss < nfs, "intermediate storage must beat NFS");
}

#[test]
fn fig8_scatter_stage2_factors() {
    let stage2 = |sys: SystemKind, hints: bool| {
        let r = execute(
            &RunSpec::cluster(sys, 5),
            &workloads::scatter(19, 1.0, hints),
        );
        r.stage_end("readRegion") - r.stage_start("readRegion")
    };
    let nfs = stage2(SystemKind::Nfs, false);
    let dss = stage2(SystemKind::DssRam, false);
    let woss = stage2(SystemKind::WossRam, true);
    assert!(nfs / woss > 5.0, "paper ~10.4x vs NFS; got {:.1}x", nfs / woss);
    assert!(dss / woss > 1.5, "paper ~2x vs DSS; got {:.1}x", dss / woss);
}

#[test]
fn fig11_bgp_shape() {
    // DSS beats GPFS and the gap grows with scale; WOSS loses its gains
    // to the Swift per-tag-op overhead (the paper's anomaly).
    let run = |sys: SystemKind, nodes: usize, hints: bool| {
        execute(
            &RunSpec::bgp(sys, nodes, 6),
            &ModFtDock::bgp(nodes, hints).build(),
        )
        .makespan
    };
    for nodes in [128usize, 256] {
        let gpfs = run(SystemKind::GpfsOnly, nodes, false);
        let dss = run(SystemKind::DssRam, nodes, false);
        let woss = run(SystemKind::WossRam, nodes, true);
        assert!(dss < gpfs, "DSS must beat GPFS at {nodes} nodes: {dss:.0} vs {gpfs:.0}");
        assert!(
            woss > dss,
            "Swift tag-op overhead must erase WOSS gains at {nodes} nodes (paper's fig11 anomaly)"
        );
    }
    // GPFS degrades with scale (metadata pressure), DSS stays flat-ish.
    let g128 = run(SystemKind::GpfsOnly, 128, false);
    let g512 = run(SystemKind::GpfsOnly, 512, false);
    assert!(g512 > g128 * 1.2, "GPFS pressure grows with the allocation");
}

#[test]
fn table4_blast_shape() {
    let run = |sys: SystemKind, rep: Option<u32>| {
        let blast = Blast {
            db_replication: rep,
            ..Default::default()
        };
        execute(&RunSpec::cluster(sys, 7), &blast.build())
    };
    let nfs = run(SystemKind::Nfs, None);
    let dss = run(SystemKind::DssRam, None);
    let r2 = run(SystemKind::WossRam, Some(2));
    let r4 = run(SystemKind::WossRam, Some(4));
    let r16 = run(SystemKind::WossRam, Some(16));

    assert!(dss.makespan < nfs.makespan, "DSS beats NFS");
    assert!(r4.makespan < dss.makespan, "WOSS r4 beats DSS");
    // Stage-in grows with the replication factor.
    assert!(r16.stage_end("stageIn") > r2.stage_end("stageIn"));
    // 16 replicas are past the optimum.
    assert!(r16.makespan > r4.makespan);
}

#[test]
fn fig14_montage_woss_wins() {
    let run = |sys: SystemKind, hints: bool| {
        let m = Montage {
            hints,
            ..Default::default()
        };
        execute(&RunSpec::cluster(sys, 8), &m.build()).makespan
    };
    let nfs = run(SystemKind::Nfs, false);
    let dss = run(SystemKind::DssDisk, false);
    let woss = run(SystemKind::WossDisk, true);
    assert!(woss < dss, "WOSS beats DSS on Montage: {woss:.1} vs {dss:.1}");
    assert!(woss < nfs, "WOSS beats NFS on Montage: {woss:.1} vs {nfs:.1}");
    assert!(
        (dss - woss) / dss > 0.05,
        "gain should be sizeable (paper ~10%)"
    );
}

#[test]
fn scale_sweep_small_files_flip() {
    // At 1/1000 the data, the overheads of tagging are no longer paid
    // off: DSS may beat WOSS and everything is within ~10%.
    let run = |sys: SystemKind, hints: bool| {
        execute(
            &RunSpec::cluster(sys, 9),
            &workloads::pipeline(19, 0.001, hints),
        )
        .workflow_span()
    };
    let dss = run(SystemKind::DssDisk, false);
    let woss = run(SystemKind::WossDisk, true);
    let diff = (woss - dss).abs() / dss;
    assert!(
        diff < 0.15,
        "tiny files: systems within ~10-15% (paper <10%); got {:.0}%",
        diff * 100.0
    );
}

#[test]
fn untagged_woss_costs_nothing_extra() {
    // Design guideline: adding cross-layer support to the *storage*
    // must not hurt applications that don't use it. Same hint-free
    // runtime (plain engine, least-loaded scheduler) over both stores.
    use woss::bench::SchedKind;
    use woss::workflow::engine::EngineConfig;
    let run = |sys: SystemKind| {
        let mut spec = RunSpec::cluster(sys, 10);
        spec.engine = Some(EngineConfig::plain(10));
        spec.scheduler = Some(SchedKind::LeastLoaded);
        execute(&spec, &workloads::pipeline(19, 1.0, false)).workflow_span()
    };
    let woss = run(SystemKind::WossRam);
    let dss = run(SystemKind::DssRam);
    let diff = (woss - dss).abs() / dss;
    assert!(diff < 0.02, "hint-free WOSS within 2% of DSS; got {:.1}%", diff * 100.0);
}
