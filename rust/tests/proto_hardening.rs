//! Protocol hardening: hostile byte streams against live daemon
//! servers. Every attack — truncated header, oversized length field,
//! checksum mismatch, unknown op code, trailing garbage, mid-stream
//! disconnect — must surface as a typed
//! [`woss::live::ProtoError`]-carrying `Malformed` reply (or a quiet
//! close when the peer is already gone). The daemon never panics,
//! never hangs, never leaks the connection: after every attack a
//! fresh connection gets clean service.
//!
//! The codec-level property (hostile bytes → typed errors, bounded
//! allocation) is pinned by `proto.rs`'s unit tests; this suite pins
//! the *server loop* behavior over real Unix sockets, in both wire
//! dialects (node and manager).

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use woss::live::proto::FRAME_MAX;
use woss::live::{
    chunk_crc, read_frame, serve_manager, serve_node, write_frame, BackendKind, LiveStore,
    ManagerRequest, ManagerResponse, MemoryBackend, NodeHost, NodeRequest, NodeResponse,
    ProtoError, RpcAddr, Server,
};

/// Per-test socket path under the system temp dir.
fn sock_addr(tag: &str) -> (RpcAddr, PathBuf) {
    let path = std::env::temp_dir().join(format!("woss-hard-{}-{tag}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    (RpcAddr::Unix(path.clone()), path)
}

/// An in-process node daemon over one memory backend.
fn node_server(tag: &str) -> (Server, PathBuf) {
    let (addr, path) = sock_addr(tag);
    let host = NodeHost::new(
        Box::new(MemoryBackend::default()),
        BackendKind::Memory,
        None,
    );
    let server = serve_node(addr, Arc::new(host)).expect("bind node server");
    (server, path)
}

fn connect(path: &PathBuf) -> UnixStream {
    let s = UnixStream::connect(path).expect("connect to daemon");
    // A hung server must fail the test, not park it forever.
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s
}

/// Send raw bytes, read the one reply frame, decode it as a node
/// response.
fn node_exchange(path: &PathBuf, raw: &[u8]) -> NodeResponse {
    let mut s = connect(path);
    s.write_all(raw).expect("send attack bytes");
    let reply = read_frame(&mut s).expect("typed reply frame");
    let (resp, _depth) = NodeResponse::decode(&reply).expect("decodable reply");
    resp
}

/// A clean request must succeed — proof the daemon is still serving.
fn assert_node_alive(path: &PathBuf) {
    let mut s = connect(path);
    write_frame(&mut s, &NodeRequest::Ping.encode()).unwrap();
    let reply = read_frame(&mut s).expect("ping reply");
    let (resp, _) = NodeResponse::decode(&reply).unwrap();
    assert_eq!(resp, NodeResponse::Ok, "daemon still serves after attack");
}

/// Frame `payload` with a deliberately wrong checksum.
fn frame_with_bad_crc(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(chunk_crc(payload) ^ 0xdead_beef).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// A correctly checksummed frame around arbitrary payload bytes.
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(&mut buf, payload).unwrap();
    buf
}

#[test]
fn node_daemon_answers_every_attack_with_a_typed_error() {
    let (server, path) = node_server("typed");

    // Checksum mismatch on an otherwise valid frame.
    let resp = node_exchange(&path, &frame_with_bad_crc(&NodeRequest::Ping.encode()));
    assert_eq!(resp, NodeResponse::Malformed(ProtoError::BadChecksum));
    assert_node_alive(&path);

    // Oversized length field: rejected from the 12-byte header alone,
    // before any payload allocation.
    let mut huge = (FRAME_MAX + 1).to_le_bytes().to_vec();
    huge.extend_from_slice(&[0u8; 8]);
    let resp = node_exchange(&path, &huge);
    assert_eq!(
        resp,
        NodeResponse::Malformed(ProtoError::Oversized((FRAME_MAX + 1) as u64))
    );
    assert_node_alive(&path);

    // An op code this dialect does not speak.
    let resp = node_exchange(&path, &frame(&[240]));
    assert_eq!(resp, NodeResponse::Malformed(ProtoError::UnknownOp(240)));
    assert_node_alive(&path);

    // A known op with trailing garbage: the strict decoder refuses
    // frames it did not consume entirely.
    let mut sloppy = NodeRequest::Ping.encode();
    sloppy.push(0);
    let resp = node_exchange(&path, &frame(&sloppy));
    assert!(
        matches!(resp, NodeResponse::Malformed(ProtoError::BadPayload(_))),
        "trailing garbage is a typed payload error, got {resp:?}"
    );
    assert_node_alive(&path);

    server.stop();
}

#[test]
fn node_daemon_survives_disconnects_and_half_frames() {
    let (server, path) = node_server("disconnect");

    // Truncated header: two bytes of the length field, then gone.
    {
        let mut s = connect(&path);
        s.write_all(&[0x10, 0x00]).unwrap();
    }
    assert_node_alive(&path);

    // Mid-stream disconnect: a full header promising 64 payload bytes,
    // ten delivered, then the peer vanishes.
    {
        let full = frame(&[7u8; 64]);
        let mut s = connect(&path);
        s.write_all(&full[..22]).unwrap();
    }
    assert_node_alive(&path);

    // Clean disconnect between frames: one good request, then close.
    {
        let mut s = connect(&path);
        write_frame(&mut s, &NodeRequest::Ping.encode()).unwrap();
        let reply = read_frame(&mut s).expect("ping reply");
        let (resp, _) = NodeResponse::decode(&reply).unwrap();
        assert_eq!(resp, NodeResponse::Ok);
    }
    assert_node_alive(&path);

    // No connection leak: a burst of hostile connections in a row,
    // then clean service.
    for i in 0..20u8 {
        let mut s = connect(&path);
        match i % 3 {
            0 => s.write_all(&frame(&[200 + i])).unwrap(),
            1 => s.write_all(&[i]).unwrap(),
            _ => s.write_all(&frame_with_bad_crc(&[i])).unwrap(),
        }
    }
    assert_node_alive(&path);

    server.stop();
}

#[test]
fn manager_daemon_speaks_its_own_malformed_dialect_and_shuts_down() {
    let (addr, path) = sock_addr("manager");
    let server = serve_manager(addr, Arc::new(LiveStore::woss(2))).expect("bind manager server");

    // Hostile op code → a *manager-dialect* Malformed reply (distinct
    // tag space from the node dialect — the reply must decode as a
    // ManagerResponse, not a NodeResponse).
    {
        let mut s = connect(&path);
        s.write_all(&frame(&[77])).unwrap();
        let reply = read_frame(&mut s).expect("typed reply frame");
        let resp = ManagerResponse::decode(&reply).expect("manager-dialect reply");
        assert_eq!(resp, ManagerResponse::Malformed(ProtoError::UnknownOp(77)));
    }

    // Clean service after the attack.
    {
        let mut s = connect(&path);
        write_frame(&mut s, &ManagerRequest::Hello.encode()).unwrap();
        let reply = read_frame(&mut s).expect("hello reply");
        match ManagerResponse::decode(&reply).unwrap() {
            ManagerResponse::Info(info) => assert_eq!(info.n_nodes, 2),
            other => panic!("hello answered {other:?}"),
        }
    }

    // A Shutdown request stops the serve loop: `wait()` returns
    // instead of parking forever.
    {
        let mut s = connect(&path);
        write_frame(&mut s, &ManagerRequest::Shutdown.encode()).unwrap();
        let reply = read_frame(&mut s).expect("shutdown acked");
        assert_eq!(
            ManagerResponse::decode(&reply).unwrap(),
            ManagerResponse::Ok
        );
    }
    server.wait();
}
