//! Backend equivalence at the workflow level: a live run on the
//! file-backed spill tier must be observably identical to the same run
//! on the in-memory backend — same fingerprints, same locality, same
//! reclamation — and deleting everything that survived must leave the
//! disk store's `--data-dir` with zero chunk files. The chunk backend
//! is a capacity decision, never a semantics decision.

use woss::hints::TagSet;
use woss::live::{
    chunk_files_under, BackendKind, CachePolicy, EngineOptions, LiveEngine, LiveReport, LiveStore,
    LiveTuning,
};
use woss::workflow::dag::{TaskSpec, Tier, Workflow};

/// A fan-out/fan-in workflow whose intermediates are all consumed (and
/// so reclaimed under lifetime tagging): preload → stageIn → 3
/// transforms → merge.
fn workflow() -> Workflow {
    let mut w = Workflow::new();
    w.preload("/backend/in", 200_000);
    w.push(
        TaskSpec::new(0, "stageIn")
            .read("/backend/in", Tier::Backend)
            .write("/w/in", Tier::Intermediate, 150_000, TagSet::from_pairs([("DP", "local")])),
    );
    for p in 0..3 {
        w.push(
            TaskSpec::new(0, "s1")
                .read("/w/in", Tier::Intermediate)
                .write(
                    &format!("/w/mid{p}"),
                    Tier::Intermediate,
                    120_000,
                    TagSet::from_pairs([("DP", "local")]),
                ),
        );
    }
    let mut merge = TaskSpec::new(0, "merge");
    for p in 0..3 {
        merge = merge.read(&format!("/w/mid{p}"), Tier::Intermediate);
    }
    merge = merge.write("/w/out", Tier::Intermediate, 100_000, TagSet::new());
    w.push(merge);
    w
}

/// One deterministic run: single worker, no prefetch races, no
/// replication tags — every counter is exact.
fn run_on(backend: BackendKind, data_dir: Option<std::path::PathBuf>) -> (LiveEngine, LiveReport) {
    let store = LiveStore::woss_with(
        4,
        LiveTuning {
            stripes: 4,
            repl_workers: 1,
            cache_bytes: Some(4 << 20),
            cache_policy: CachePolicy::HintAware,
            lifetime: true,
            backend,
            data_dir,
            fault: None,
        },
    );
    let engine = LiveEngine::with_options(
        store,
        1,
        EngineOptions {
            lifetime: true,
            prefetch: false,
        },
    )
    .unwrap();
    let report = engine.run(&workflow()).unwrap();
    (engine, report)
}

#[test]
fn disk_run_matches_memory_run_and_cleans_its_data_dir() {
    let dir = std::env::temp_dir().join(format!(
        "woss-backend-equivalence-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    let (mem_engine, mem) = run_on(BackendKind::Memory, None);
    let (disk_engine, disk) = run_on(BackendKind::Disk, Some(dir.clone()));

    assert_eq!(mem.backend, "mem");
    assert_eq!(disk.backend, "disk");
    assert_eq!(mem.tasks, disk.tasks);
    assert_eq!(
        mem.fingerprints, disk.fingerprints,
        "identical output checksums on both backends"
    );
    assert!(!mem.fingerprints.is_empty());
    assert_eq!(
        (mem.local_reads, mem.remote_reads),
        (disk.local_reads, disk.remote_reads),
        "identical locality on both backends"
    );
    assert_eq!(mem.locality(), disk.locality());
    assert_eq!(
        (mem.files_reclaimed, mem.bytes_reclaimed),
        (disk.files_reclaimed, disk.bytes_reclaimed),
        "identical reclamation on both backends"
    );
    assert_eq!(
        mem.files_reclaimed, 4,
        "/w/in and the three mids die with their last consumer"
    );

    // Both runs re-verify their fingerprinted files end to end.
    assert_eq!(
        mem_engine.verify(&mem).unwrap(),
        disk_engine.verify(&disk).unwrap()
    );

    // What survived the run is really on disk; deleting it removes
    // every spilled file.
    assert!(
        chunk_files_under(&dir) > 0,
        "durable survivors live in the data dir"
    );
    for path in ["/backend/in", "/w/out"] {
        disk_engine.store().delete(path).unwrap();
        mem_engine.store().delete(path).unwrap();
    }
    assert_eq!(
        chunk_files_under(&dir),
        0,
        "reclaim + delete leave zero files in --data-dir"
    );
    assert_eq!(
        disk_engine.store().backend_used_bytes().iter().sum::<u64>(),
        0
    );

    drop(disk_engine);
    assert!(dir.exists(), "a user-supplied data_dir is never deleted");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn disk_backend_survives_footprint_beyond_cache_budget() {
    // The capacity story the memory store could not tell: a working
    // set several times the cache budget streams through the disk
    // backend — dirty scratch chunks write back under pressure, every
    // byte stays readable, and the cache stays within budget.
    let budget: u64 = 2 * 256 * 1024; // two chunks
    let store = LiveStore::woss_with(
        3,
        LiveTuning {
            stripes: 4,
            repl_workers: 1,
            cache_bytes: Some(budget),
            cache_policy: CachePolicy::HintAware,
            lifetime: true,
            backend: BackendKind::Disk,
            data_dir: None, // auto temp dir, removed when the store drops
            fault: None,
        },
    );
    use woss::storage::NodeId;
    let scratch = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
    let payload = vec![0xABu8; 400_000]; // ~1.5 chunks per file
    for f in 0..12 {
        store
            .write_file(NodeId(0), &format!("/big{f}"), &payload, &scratch)
            .unwrap();
    }
    let stats = store.cache_stats();
    assert!(
        stats.spilled > 0,
        "a footprint beyond the budget forces write-backs"
    );
    assert!(stats.peak_node_resident <= budget, "cache stayed bounded");
    for f in 0..12 {
        assert_eq!(
            store.read_file(NodeId(1), &format!("/big{f}")).unwrap(),
            payload,
            "file {f} readable after spill"
        );
    }
}
