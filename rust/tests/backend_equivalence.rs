//! Backend equivalence at the workflow level: a live run must be
//! observably identical across every chunk backend — in-memory,
//! file-per-chunk disk spill, and the packed segment log — same
//! fingerprints, same locality, same reclamation — and deleting
//! everything that survived must leave a persistent backend's
//! `--data-dir` holding zero chunk bytes. The chunk backend is a
//! capacity/layout decision, never a semantics decision.
//!
//! Workload sizes are drawn from the seeded `tests/common` harness, so
//! a failing shape is replayable with `WOSS_TEST_SEED=<seed>`.

mod common;

use woss::hints::TagSet;
use woss::live::{
    chunk_files_under, segment_files_under, BackendKind, CachePolicy, EngineOptions, LiveEngine,
    LiveReport, LiveStore, LiveTuning,
};
use woss::util::Rng;
use woss::workflow::dag::{TaskSpec, Tier, Workflow};

/// A fan-out/fan-in workflow whose intermediates are all consumed (and
/// so reclaimed under lifetime tagging): preload → stageIn → 3
/// transforms → merge. Sizes come from the seeded RNG — every backend
/// in the matrix is built from the same seed, so they see the same
/// shape.
fn workflow(rng: &mut Rng) -> Workflow {
    let mut w = Workflow::new();
    w.preload("/backend/in", 150_000 + rng.gen_range(100_000) as usize);
    w.push(
        TaskSpec::new(0, "stageIn")
            .read("/backend/in", Tier::Backend)
            .write(
                "/w/in",
                Tier::Intermediate,
                100_000 + rng.gen_range(100_000) as usize,
                TagSet::from_pairs([("DP", "local")]),
            ),
    );
    for p in 0..3 {
        w.push(
            TaskSpec::new(0, "s1")
                .read("/w/in", Tier::Intermediate)
                .write(
                    &format!("/w/mid{p}"),
                    Tier::Intermediate,
                    80_000 + rng.gen_range(80_000) as usize,
                    TagSet::from_pairs([("DP", "local")]),
                ),
        );
    }
    let mut merge = TaskSpec::new(0, "merge");
    for p in 0..3 {
        merge = merge.read(&format!("/w/mid{p}"), Tier::Intermediate);
    }
    merge = merge.write(
        "/w/out",
        Tier::Intermediate,
        80_000 + rng.gen_range(40_000) as usize,
        TagSet::new(),
    );
    w.push(merge);
    w
}

/// One deterministic run: single worker, no prefetch races, no
/// replication tags — every counter is exact. The workflow is rebuilt
/// from the seed, so every backend runs the identical shape.
fn run_on(
    seed: u64,
    backend: BackendKind,
    data_dir: Option<std::path::PathBuf>,
) -> (LiveEngine, LiveReport) {
    let store = LiveStore::woss_with(
        4,
        LiveTuning {
            stripes: 4,
            repl_workers: 1,
            cache_bytes: Some(4 << 20),
            cache_policy: CachePolicy::HintAware,
            lifetime: true,
            backend,
            data_dir,
            fault: None,
            io_workers: 1,
            adaptive: false,
        },
    );
    let engine = LiveEngine::with_options(
        store,
        1,
        EngineOptions {
            lifetime: true,
            prefetch: false,
        },
    )
    .unwrap();
    let report = engine.run(&workflow(&mut Rng::new(seed))).unwrap();
    (engine, report)
}

#[test]
fn every_backend_matches_memory_and_cleans_its_data_dir() {
    let (seed, _rng) = common::seeded_rng("backend_equivalence");
    let (mem_engine, mem) = run_on(seed, BackendKind::Memory, None);
    assert_eq!(mem.backend, "mem");
    assert!(!mem.fingerprints.is_empty());
    assert_eq!(
        mem.files_reclaimed, 4,
        "/w/in and the three mids die with their last consumer"
    );

    for kind in [BackendKind::Disk, BackendKind::Seg] {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-equivalence-{}-{}",
            kind.label(),
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let (engine, rep) = run_on(seed, kind, Some(dir.clone()));

        assert_eq!(rep.backend, kind.label());
        assert_eq!(mem.tasks, rep.tasks);
        assert_eq!(
            mem.fingerprints, rep.fingerprints,
            "identical output checksums on {kind:?} (seed={seed})"
        );
        assert_eq!(
            (mem.local_reads, mem.remote_reads),
            (rep.local_reads, rep.remote_reads),
            "identical locality on {kind:?} (seed={seed})"
        );
        assert_eq!(mem.locality(), rep.locality());
        assert_eq!(
            (mem.files_reclaimed, mem.bytes_reclaimed),
            (rep.files_reclaimed, rep.bytes_reclaimed),
            "identical reclamation on {kind:?} (seed={seed})"
        );

        // Both runs re-verify their fingerprinted files end to end.
        assert_eq!(
            mem_engine.verify(&mem).unwrap(),
            engine.verify(&rep).unwrap()
        );

        // Physical layout matches the backend's contract: one file per
        // chunk on `disk`, a few packed logs (and zero per-chunk
        // files) on `seg`.
        match kind {
            BackendKind::Seg => {
                assert!(
                    segment_files_under(&dir) > 0,
                    "durable survivors live in the segment logs"
                );
                assert_eq!(chunk_files_under(&dir), 0, "no per-chunk files on seg");
            }
            _ => {
                assert!(
                    chunk_files_under(&dir) > 0,
                    "durable survivors live in the data dir"
                );
                assert_eq!(segment_files_under(&dir), 0, "no segment logs on disk");
            }
        }

        // What survived the run is really on disk; deleting it returns
        // every byte on both layouts.
        for path in ["/backend/in", "/w/out"] {
            engine.store().delete(path).unwrap();
        }
        assert_eq!(
            chunk_files_under(&dir),
            0,
            "reclaim + delete leave zero chunk files in --data-dir"
        );
        assert!(
            segment_files_under(&dir) <= 4,
            "segment count stays O(segments) — at most one active log per node"
        );
        assert_eq!(
            engine.store().backend_used_bytes().iter().sum::<u64>(),
            0,
            "delete + maintenance returned every byte on {kind:?}"
        );

        drop(engine);
        assert!(dir.exists(), "a user-supplied data_dir is never deleted");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn persistent_backends_survive_footprint_beyond_cache_budget() {
    // The capacity story the memory store could not tell: a working
    // set several times the cache budget streams through each
    // persistent backend — dirty scratch chunks write back under
    // pressure, every byte stays readable, and the cache stays within
    // budget.
    for kind in [BackendKind::Disk, BackendKind::Seg] {
        let budget: u64 = 2 * 256 * 1024; // two chunks
        let store = LiveStore::woss_with(
            3,
            LiveTuning {
                stripes: 4,
                repl_workers: 1,
                cache_bytes: Some(budget),
                cache_policy: CachePolicy::HintAware,
                lifetime: true,
                backend: kind,
                data_dir: None, // auto temp dir, removed when the store drops
                fault: None,
                io_workers: 1,
                adaptive: false,
            },
        );
        use woss::storage::NodeId;
        let scratch = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
        let payload = vec![0xABu8; 400_000]; // ~1.5 chunks per file
        for f in 0..12 {
            store
                .write_file(NodeId(0), &format!("/big{f}"), &payload, &scratch)
                .unwrap();
        }
        let stats = store.cache_stats();
        assert!(
            stats.spilled > 0,
            "a footprint beyond the budget forces write-backs on {kind:?}"
        );
        assert!(stats.peak_node_resident <= budget, "cache stayed bounded");
        for f in 0..12 {
            assert_eq!(
                store.read_file(NodeId(1), &format!("/big{f}")).unwrap(),
                payload,
                "file {f} readable after spill on {kind:?}"
            );
        }
    }
}
