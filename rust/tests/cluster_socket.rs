//! Real-process cluster legs: `woss noded` daemons spawned as child
//! processes over Unix sockets, driven through the same `LiveStore`
//! API the in-process tier uses. Pins the tentpole's transport
//! equivalence (a manager served over the wire produces byte-identical
//! engine fingerprints) and the churn contract: `fail_node` is a real
//! SIGKILL of a real daemon, recovery is a real respawn — with
//! `--reopen` salvage on persistent backends.
//!
//! Every test routes `Cluster::spawn` at the cargo-built `woss` binary
//! via `WOSS_BIN` (inside a test harness, `current_exe()` is the test
//! binary itself, which has no `noded` subcommand).

use std::path::PathBuf;
use std::sync::Arc;

use woss::dispatch::Registry;
use woss::hints::TagSet;
use woss::live::{
    serve_manager, store_over_cluster, BackendKind, Cluster, EngineOptions, LiveEngine, LiveStore,
    LiveTuning, ManagerService, RemoteStore, RpcAddr, StoreHandle,
};
use woss::scenario::{self, ScenarioConfig, Transport};
use woss::storage::NodeId;
use woss::workloads;

/// Point `Cluster::spawn` at the real `woss` binary.
fn point_at_woss_bin() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| std::env::set_var("WOSS_BIN", env!("CARGO_BIN_EXE_woss")));
}

/// Deterministic per-file payload bytes.
fn payload(i: usize) -> Vec<u8> {
    (0..40_000 + i * 1_111)
        .map(|j| ((j as u64).wrapping_mul(31).wrapping_add(i as u64 * 7)) as u8)
        .collect()
}

/// Is an OS process with this pid still around? (`Cluster::kill` reaps,
/// so a killed daemon's `/proc` entry disappears — no zombie.)
fn process_alive(pid: u32) -> bool {
    std::path::Path::new(&format!("/proc/{pid}")).exists()
}

#[test]
fn socket_cluster_serves_bytes_and_survives_real_process_death() {
    point_at_woss_bin();
    let cluster = Cluster::spawn(3, BackendKind::Memory, None).expect("spawn mem cluster");
    let store = store_over_cluster(
        Registry::woss(),
        &cluster,
        u64::MAX / 2,
        LiveTuning::default(),
    );

    // Every chunk twice-held before the churn starts.
    let tags = TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "pessimistic")]);
    let n_files = 6;
    for i in 0..n_files {
        store
            .write_file(NodeId(i % 3), &format!("/wire/f{i}"), &payload(i), &tags)
            .expect("write over the wire");
    }
    store.flush_replication();
    for i in 0..n_files {
        assert!(store.fully_replicated(&format!("/wire/f{i}")).unwrap());
        let got = store.read_file(NodeId((i + 1) % 3), &format!("/wire/f{i}")).unwrap();
        assert_eq!(got, payload(i), "roundtrip bytes over sockets");
    }

    // fail_node must kill the actual daemon process, not flip a flag.
    let victim = store.locations("/wire/f0")[0];
    let pid = cluster.pid(victim.0).expect("daemon running");
    assert!(process_alive(pid), "victim daemon alive before the kill");
    let queued = store.fail_node(victim);
    assert!(queued > 0, "the victim held chunks, restores must queue");
    assert!(cluster.pid(victim.0).is_none(), "child reaped after kill");
    assert!(!process_alive(pid), "the OS process is really gone");

    // Survivors re-replicate and keep serving every byte.
    store.flush_replication();
    assert_eq!(store.under_replicated(), 0);
    for i in 0..n_files {
        let client = NodeId((i + 2) % 3);
        let got = store.read_file(client, &format!("/wire/f{i}")).unwrap();
        assert_eq!(got, payload(i), "bytes survive a daemon death");
    }

    // join_node respawns a fresh daemon process on the same socket.
    store.join_node(victim);
    assert!(store.is_alive(victim), "rejoined node serves again");
    let new_pid = cluster.pid(victim.0).expect("respawned daemon");
    assert_ne!(new_pid, pid, "a new process, not a resurrected flag");
    assert!(process_alive(new_pid));

    store.flush_replication();
    let audit = store.audit();
    assert!(audit.clean(), "{audit:?}");
}

/// `kill_recover` in socket mode: the scenario's node kill is a real
/// `SIGKILL` of a `noded` child, recovery respawns it with `--reopen`
/// (manifest/segment salvage on persistent backends), and the
/// scenario's own byte-verification audit must close clean. Runs on
/// both persistent layouts so both salvage paths cross the process
/// boundary.
#[test]
fn kill_recover_over_sockets_salvages_both_persistent_backends() {
    point_at_woss_bin();
    for backend in [BackendKind::Disk, BackendKind::Seg] {
        let cfg = ScenarioConfig {
            quick: true,
            backend,
            transport: Transport::Socket,
            ..ScenarioConfig::default()
        };
        let rep = scenario::run("kill_recover", &cfg)
            .unwrap_or_else(|e| panic!("kill_recover socket/{}: {e}", backend.label()));
        assert!(rep.clean(), "dirty socket run on {}: {rep:?}", backend.label());
        assert_eq!(rep.transport, "socket");
        assert!(
            rep.recovery_secs.is_some(),
            "recovery clock must run on {}",
            backend.label()
        );
        assert!(
            rep.bytes_rereplicated > 0,
            "churn must move real bytes on {}",
            backend.label()
        );
        assert_eq!(
            rep.read_p99_ms_wire,
            Some(rep.read_p99_ms),
            "a socket-primary run records its own p99 as the wire column"
        );
    }
}

/// The tentpole equivalence claim at the manager boundary: the same
/// workflow driven through a `RemoteStore` client against a served
/// manager produces the same task count, the same bytes written, and
/// byte-identical output fingerprints as the in-process store — and
/// each side's fingerprints verify against the *other* side's store.
#[test]
fn manager_over_socket_matches_in_process_engine_run() {
    let wf = workloads::pipeline(3, 0.01, true);

    // Leg 1: classic in-process store.
    let local_engine =
        LiveEngine::with_options(LiveStore::woss(3), 2, EngineOptions::default()).unwrap();
    let local_rep = local_engine.run(&wf).expect("local run");
    local_engine.verify(&local_rep).expect("local verify");

    // Leg 2: identical store served over a Unix socket, driven through
    // the RemoteStore client library.
    let sock = std::env::temp_dir().join(format!("woss-mgr-eq-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let server = serve_manager(
        RpcAddr::Unix(PathBuf::from(&sock)),
        Arc::new(LiveStore::woss(3)),
    )
    .expect("bind manager");
    let remote = RemoteStore::connect(server.addr().clone()).expect("connect manager");
    let handle = StoreHandle::Remote(Arc::new(remote));
    let remote_engine = LiveEngine::with_handle(handle.clone(), 2, EngineOptions::default())
        .expect("engine over socket");
    let remote_rep = remote_engine.run(&wf).expect("remote run");
    remote_engine.verify(&remote_rep).expect("remote verify");

    assert_eq!(local_rep.tasks, remote_rep.tasks, "same DAG executed");
    assert_eq!(
        local_rep.bytes_written, remote_rep.bytes_written,
        "same bytes moved through both transports"
    );
    assert_eq!(
        local_rep.fingerprints, remote_rep.fingerprints,
        "output bytes identical across transports"
    );
    // Cross-check: each store holds bytes matching the OTHER leg's
    // fingerprints.
    local_engine
        .verify_fingerprints(&remote_rep.fingerprints)
        .expect("remote fingerprints verify against the local store");
    remote_engine
        .verify_fingerprints(&local_rep.fingerprints)
        .expect("local fingerprints verify against the served store");

    // Shutdown over the wire stops the serve loop.
    handle.svc().shutdown_store();
    server.wait();
}
