//! Overlap tests for the pipelined disk data path: with a seeded
//! latency spike pinned to one node's backend, concurrent cache
//! hits and a second node's puts must complete *while the slow
//! operation is still in flight* — the store never holds a lock across
//! disk I/O, so one slow disk serializes nothing but itself.
//!
//! The spike is detected mid-flight through [`FaultControl::delays`]
//! (the injector counts a spike *before* it sleeps), and every
//! concurrent operation runs under a deadline: a regression that
//! re-introduces a lock held across the spiking I/O shows up as the
//! deadline firing, not as a hang.
//!
//! Determinism: the fault schedule is a pure function of the harness
//! seed (`WOSS_TEST_SEED` replays it), `delay_permille: 1000` fires on
//! every selected node-0 backend operation, and `delay_node` keeps
//! node 1 spike-free.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};
use woss::dispatch::Registry;
use woss::hints::TagSet;
use woss::live::{BackendKind, FaultSpec, LiveStore, LiveTuning};
use woss::storage::NodeId;
use woss::util::Rng;

/// How long the injected spike parks node 0's backend operation.
const SPIKE_US: u64 = 1_500_000;
/// Assertion timeout for everything that must NOT wait on the spike.
const DEADLINE: Duration = Duration::from_secs(10);

/// Deterministic payload bytes from the harness seed.
fn payload(seed: u64, salt: u64, len: usize) -> Vec<u8> {
    let mut rng = Rng::new(seed ^ salt);
    let mult = rng.next_u64() | 1;
    (0..len)
        .map(|i| ((i as u64).wrapping_mul(mult) >> 3) as u8)
        .collect()
}

/// A spike schedule pinned to node 0: every node-0 backend put/get
/// sleeps [`SPIKE_US`]; node 1 never spikes.
fn spike_spec(seed: u64) -> FaultSpec {
    FaultSpec {
        seed,
        delay_permille: 1000,
        delay_us: SPIKE_US,
        delay_node: Some(0),
        ..FaultSpec::default()
    }
}

/// Busy-wait (with a deadline) until the injector reports at least one
/// spike in flight or already fired.
fn await_spike_started(store: &LiveStore, seed: u64) {
    let ctl = store.fault_control().expect("fault-injecting store");
    let t0 = Instant::now();
    while ctl.delays() < 1 {
        assert!(
            t0.elapsed() < DEADLINE,
            "spike never started (seed={seed})"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Memory backend: a foreground put parked on node 0's backend blocks
/// neither node-0 cache hits nor node-1 puts — the data path runs
/// outside every store lock on the mem tier too.
#[test]
fn mem_slow_put_overlaps_cache_hits_and_other_nodes() {
    let (seed, _rng) = common::seeded_rng("mem_slow_put_overlaps");
    let store = LiveStore::try_with_tuning(
        Registry::woss(),
        2,
        u64::MAX / 2,
        LiveTuning {
            cache_bytes: Some(4 << 20),
            fault: Some(spike_spec(seed)),
            ..LiveTuning::default()
        },
    )
    .expect("mem store");
    let ctl = store.fault_control().unwrap();
    ctl.set_enabled(false);

    // Warm-up (no spikes): /warm lives on node 1; two reads from node 0
    // leave a node-0 cached copy, so re-reads are pure cache hits that
    // never touch node 0's (spiking) backend.
    let local = TagSet::from_pairs([("DP", "local")]);
    let warm = payload(seed, 1, 100_000);
    store.write_file(NodeId(1), "/warm", &warm, &local).unwrap();
    store.read_file(NodeId(0), "/warm").unwrap();
    assert_eq!(store.read_file(NodeId(0), "/warm").unwrap(), warm);
    assert!(store.cache_stats().hits >= 1, "warm copy is cache-resident");

    ctl.set_enabled(true);
    let slow = payload(seed, 2, 200_000);
    let n1 = payload(seed, 3, 100_000);
    let slow_done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            // Primary copy lands on node 0 → spike fires inside the
            // unlocked backend put.
            store.write_file(NodeId(0), "/slow", &slow, &local).unwrap();
            slow_done.store(true, Ordering::SeqCst);
        });
        await_spike_started(&store, seed);

        // Both of these must complete while /slow is still parked.
        let t = Instant::now();
        assert_eq!(store.read_file(NodeId(0), "/warm").unwrap(), warm);
        store.write_file(NodeId(1), "/n1", &n1, &local).unwrap();
        assert!(
            t.elapsed() < DEADLINE,
            "concurrent ops blew the deadline (seed={seed})"
        );
        assert!(
            !slow_done.load(Ordering::SeqCst),
            "cache hit + node-1 put finished only after the slow put — \
             no overlap (seed={seed})"
        );
    });

    ctl.set_enabled(false);
    assert_eq!(store.read_file(NodeId(1), "/slow").unwrap(), slow);
    assert_eq!(store.read_file(NodeId(0), "/n1").unwrap(), n1);
    assert!(store.audit().clean(), "closing audit (seed={seed})");
}

/// Disk backend, `io_workers = 4`: a dirty scratch chunk's spill parks
/// on node 0's disk mid-write-back. The `Spilling` entry protocol keeps
/// the node's cache mutex free, so node-0 cache hits and node-1 puts
/// proceed, and the `io_queue=` gauge reports the in-flight submission.
#[test]
fn disk_spill_overlaps_cache_hits_and_other_nodes() {
    let (seed, _rng) = common::seeded_rng("disk_spill_overlaps");
    let dir = std::env::temp_dir().join(format!("woss-overlap-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = LiveStore::try_with_tuning(
        Registry::woss(),
        2,
        u64::MAX / 2,
        LiveTuning {
            cache_bytes: Some(400_000),
            lifetime: true,
            backend: BackendKind::Disk,
            data_dir: Some(dir.clone()),
            fault: Some(spike_spec(seed)),
            io_workers: 4,
            ..LiveTuning::default()
        },
    )
    .expect("disk store");
    let ctl = store.fault_control().unwrap();
    ctl.set_enabled(false);

    // /s0: scratch on the disk tier skips the spill — a dirty
    // cache-only chunk on node 0, the victim-to-be.
    let scratch = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
    let local = TagSet::from_pairs([("DP", "local")]);
    let s0 = payload(seed, 10, 200_000);
    store.write_file(NodeId(0), "/s0", &s0, &scratch).unwrap();
    // /warm: durable on node 1, cached on node 0 by the reads below.
    let warm = payload(seed, 11, 100_000);
    store.write_file(NodeId(1), "/warm", &warm, &local).unwrap();
    store.read_file(NodeId(0), "/warm").unwrap();
    assert_eq!(store.read_file(NodeId(0), "/warm").unwrap(), warm);

    ctl.set_enabled(true);
    // /s1 needs room on node 0: the hint-aware policy picks the dirty
    // scratch entry (/s0) as victim → Spilling → disk put → spike.
    let s1 = payload(seed, 12, 200_000);
    let n1 = payload(seed, 13, 100_000);
    let spill_done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            store.write_file(NodeId(0), "/s1", &s1, &scratch).unwrap();
            spill_done.store(true, Ordering::SeqCst);
        });
        await_spike_started(&store, seed);

        // The bottom-up gauge sees the parked submission.
        let status = store.get_xattr("/warm", "system_status").unwrap();
        let depth: usize = status
            .rsplit("io_queue=")
            .next()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("system_status lacks io_queue: {status}"));
        assert!(depth >= 1, "spill in flight must show in io_queue: {status}");

        // Node-0 cache hits and node-1 puts proceed mid-spill.
        let t = Instant::now();
        assert_eq!(store.read_file(NodeId(0), "/warm").unwrap(), warm);
        store.write_file(NodeId(1), "/n1", &n1, &local).unwrap();
        assert!(
            t.elapsed() < DEADLINE,
            "concurrent ops blew the deadline (seed={seed})"
        );
        assert!(
            !spill_done.load(Ordering::SeqCst),
            "cache hit + node-1 put finished only after the spill — \
             no overlap (seed={seed})"
        );
    });

    ctl.set_enabled(false);
    store.flush_replication();
    let stats = store.cache_stats();
    assert!(stats.spilled >= 1, "the dirty victim was written back");
    assert!(stats.spill_p99_us > 0.0, "spill latency was sampled");
    assert_eq!(store.read_file(NodeId(0), "/s0").unwrap(), s0, "spilled bytes");
    assert_eq!(store.read_file(NodeId(0), "/s1").unwrap(), s1);
    assert_eq!(store.read_file(NodeId(1), "/n1").unwrap(), n1);
    assert!(store.audit().clean(), "closing audit (seed={seed})");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
