//! Edge-case and failure-path coverage across module boundaries.

use woss::hints::TagSet;
use woss::nfs::NfsServer;
use woss::sim::{Calib, Cluster, DiskKind, SimTime};
use woss::storage::{standard_deployment, NodeId, StorageModel};
use woss::workflow::dag::{TaskSpec, Tier, Workflow};
use woss::workflow::engine::{run_workflow, EngineConfig};
use woss::workflow::scheduler::{LeastLoaded, LocationAware};

fn cluster() -> Cluster {
    Cluster::new(6, DiskKind::RamDisk, &Calib::default())
}

#[test]
fn empty_workflow_completes_instantly() {
    let mut cl = cluster();
    let mut inter = standard_deployment(&cl, true, true, 1);
    let mut backend = NfsServer::new(&Calib::default());
    let mut sched = LocationAware::new();
    let result = run_workflow(
        &mut cl,
        &mut inter,
        &mut backend,
        &mut sched,
        EngineConfig::woss(1),
        &Workflow::new(),
    )
    .unwrap();
    assert_eq!(result.tasks.len(), 0);
    assert_eq!(result.makespan, 0.0);
}

#[test]
fn pinned_tasks_run_where_pinned() {
    let mut w = Workflow::new();
    w.preload("/backend/in", 1 << 20);
    w.push(
        TaskSpec::new(0, "stageIn")
            .read("/backend/in", Tier::Backend)
            .write("/w/a", Tier::Intermediate, 1 << 20, TagSet::new())
            .pin_to(NodeId(4)),
    );
    w.push(
        TaskSpec::new(0, "work")
            .read("/w/a", Tier::Intermediate)
            .write("/w/b", Tier::Intermediate, 1 << 20, TagSet::new())
            .pin_to(NodeId(2))
            .compute(0.1),
    );
    let mut cl = cluster();
    let mut inter = standard_deployment(&cl, true, true, 2);
    let mut backend = NfsServer::new(&Calib::default());
    let mut sched = LeastLoaded::new();
    let result = run_workflow(
        &mut cl,
        &mut inter,
        &mut backend,
        &mut sched,
        EngineConfig::plain(2),
        &w,
    )
    .unwrap();
    let node_of = |stage: &str| {
        result
            .tasks
            .iter()
            .find(|t| t.stage == stage)
            .map(|t| t.node)
            .unwrap()
    };
    assert_eq!(node_of("stageIn"), NodeId(4));
    assert_eq!(node_of("work"), NodeId(2));
}

#[test]
fn barrier_off_lets_stages_overlap() {
    // Two pipelines; with the barrier off, pipeline 1's stage1 may start
    // before pipeline 2's stageIn completes.
    let build = || woss::workloads::pipeline(4, 1.0, false);
    let run = |barrier: bool| {
        let mut cl = Cluster::new(8, DiskKind::RamDisk, &Calib::default());
        let mut inter = standard_deployment(&cl, false, true, 3);
        let mut backend = NfsServer::new(&Calib::default());
        let mut sched = LeastLoaded::new();
        let cfg = EngineConfig {
            stage_in_barrier: barrier,
            ..EngineConfig::plain(3)
        };
        run_workflow(&mut cl, &mut inter, &mut backend, &mut sched, cfg, &build()).unwrap()
    };
    let with_barrier = run(true);
    let without = run(false);
    assert!(
        without.makespan <= with_barrier.makespan + 1e-9,
        "overlap can only help the makespan"
    );
    let first_stage1 = without.stage_start("stage1");
    let last_stage_in = without.stage_end("stageIn");
    assert!(
        first_stage1 < last_stage_in,
        "stages must overlap staging when the barrier is off"
    );
}

#[test]
fn block_size_hint_changes_layout_only_for_woss() {
    let mut cl = cluster();
    let mut woss = standard_deployment(&cl, true, true, 4);
    let tags = TagSet::from_pairs([("BlockSize", "64K"), ("DP", "scatter 1")]);
    woss.write_file(&mut cl, NodeId(1), "/s", 512 * 1024, &tags, SimTime::ZERO)
        .unwrap();
    // 8 × 64 KB blocks scattered one per node (5 storage nodes): >1 holder.
    assert!(woss.locations("/s").len() > 1);

    let mut cl2 = cluster();
    let mut dss = standard_deployment(&cl2, false, true, 4);
    dss.write_file(&mut cl2, NodeId(1), "/s", 512 * 1024, &tags, SimTime::ZERO)
        .unwrap();
    // DSS ignores BlockSize: 512 KB < 1 MB default chunk → single chunk.
    assert!(dss.locations("/s").is_empty(), "DSS exposes nothing");
}

#[test]
fn system_status_attribute_reports_pool() {
    let mut cl = cluster();
    let mut woss = standard_deployment(&cl, true, true, 5);
    woss.write_file(&mut cl, NodeId(1), "/f", 1 << 20, &TagSet::new(), SimTime::ZERO)
        .unwrap();
    let (status, _) = woss
        .get_xattr(&mut cl, NodeId(1), "/f", "system_status", SimTime::ZERO)
        .unwrap();
    let status = status.expect("system_status served");
    assert!(status.contains("nodes=5"), "{status}");
    assert!(status.contains("used="), "{status}");
}

#[test]
fn double_create_rejected_everywhere() {
    let mut cl = cluster();
    let calib = Calib::default();
    let mut woss = standard_deployment(&cl, true, true, 6);
    let mut nfs = NfsServer::new(&calib);
    woss.write_file(&mut cl, NodeId(1), "/dup", 1024, &TagSet::new(), SimTime::ZERO)
        .unwrap();
    assert!(woss
        .write_file(&mut cl, NodeId(1), "/dup", 1024, &TagSet::new(), SimTime::ZERO)
        .is_err());
    // NFS overwrites (close-to-open semantics allow it).
    nfs.write_file(&mut cl, NodeId(1), "/dup", 1024, &TagSet::new(), SimTime::ZERO)
        .unwrap();
    assert!(nfs
        .write_file(&mut cl, NodeId(1), "/dup", 2048, &TagSet::new(), SimTime::ZERO)
        .is_ok());
    assert_eq!(nfs.file_size("/dup"), Some(2048));
}

#[test]
fn gpfs_xattr_roundtrip_and_delete() {
    let calib = Calib::bgp();
    let mut cl = Cluster::new(8, DiskKind::RamDisk, &calib);
    let mut gpfs = woss::gpfs::Gpfs::new(&calib);
    gpfs.write_file(&mut cl, NodeId(1), "/g", 4 << 20, &TagSet::new(), SimTime::ZERO)
        .unwrap();
    gpfs.set_xattr(&mut cl, NodeId(1), "/g", "DP", "local", SimTime::ZERO)
        .unwrap();
    let (v, _) = gpfs
        .get_xattr(&mut cl, NodeId(2), "/g", "DP", SimTime::ZERO)
        .unwrap();
    assert_eq!(v.as_deref(), Some("local"), "stored verbatim, never acted on");
    gpfs.delete("/g").unwrap();
    assert!(gpfs.read_file(&mut cl, NodeId(1), "/g", SimTime::ZERO).is_err());
}

#[test]
fn scatter_range_scheduling_targets_owning_node() {
    // Fine-grained location exposure: each region maps to exactly one
    // node, and different regions map to different nodes.
    let mut cl = Cluster::new(8, DiskKind::RamDisk, &Calib::default());
    let mut woss = standard_deployment(&cl, true, true, 7);
    let region = 2u64 << 20;
    let tags = TagSet::from_pairs([("DP", "scatter 1"), ("BlockSize", &region.to_string())]);
    woss.write_file(&mut cl, NodeId(1), "/sc", region * 6, &tags, SimTime::ZERO)
        .unwrap();
    let mut owners = Vec::new();
    for r in 0..6 {
        let o = woss.locations_range("/sc", r * region, region);
        assert_eq!(o.len(), 1, "region {r} owned by one node");
        owners.push(o[0]);
    }
    owners.sort_unstable();
    owners.dedup();
    assert!(owners.len() > 1, "regions spread across nodes");
}
