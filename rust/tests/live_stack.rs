//! Integration: the full three-layer stack (coordinator → live store →
//! PJRT kernels). Skips gracefully when `make artifacts` has not run.

use woss::live::{LiveEngine, LiveStore};
use woss::runtime::Runtime;
use woss::workloads::{self, Montage};

fn artifacts_present() -> bool {
    Runtime::artifact_dir()
        .join("stage_transform.hlo.txt")
        .exists()
}

#[test]
fn live_montage_completes_and_verifies() {
    if !artifacts_present() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let engine = LiveEngine::new(LiveStore::woss(6), 6).unwrap();
    let wf = Montage {
        inputs: 8,
        hints: true,
        scale: 0.02,
    }
    .build();
    let report = engine.run(&wf).unwrap();
    assert_eq!(report.tasks, wf.tasks.len());
    assert!(report.bytes_written > 0 && report.bytes_read > 0);
    assert!(report.kernel_execs["reduce_merge"] > 0, "reduce tasks ran the merge kernel");
    assert!(report.kernel_execs["stage_transform"] > 0);
    let verified = engine.verify(&report).unwrap();
    assert_eq!(verified, report.fingerprints.len());
    assert!(verified > 20, "montage produces many verified files: {verified}");
}

#[test]
fn live_pipeline_hints_improve_locality() {
    if !artifacts_present() {
        return;
    }
    let wf = |hints| workloads::pipeline(6, 0.002, hints);
    let woss = LiveEngine::new(LiveStore::woss(6), 4).unwrap();
    let rw = woss.run(&wf(true)).unwrap();
    let dss = LiveEngine::new(LiveStore::dss(6), 4).unwrap();
    let rd = dss.run(&wf(false)).unwrap();
    assert!(
        rw.locality() >= rd.locality(),
        "WOSS {:.2} vs DSS {:.2}",
        rw.locality(),
        rd.locality()
    );
}

#[test]
fn live_runtime_kernels_match_oracles() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::load(&Runtime::artifact_dir()).unwrap();
    let tile: Vec<f32> = (0..woss::runtime::TILE_ELEMS)
        .map(|i| ((i % 97) as f32) / 97.0)
        .collect();
    let got = rt.checksum(&tile).unwrap();
    let want = woss::runtime::checksum_ref(&tile);
    assert!((got - want).abs() <= want.abs() * 1e-4);
}
