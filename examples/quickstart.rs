//! Quickstart: the cross-layer channel in five minutes.
//!
//! Builds a tiny WOSS deployment on the simulated cluster, shows the
//! top-down channel (tagging a file with `DP=local` / `Replication`),
//! the bottom-up channel (reading the reserved `location` attribute),
//! and the end-to-end payoff (a tagged pipeline vs the untagged
//! baseline).
//!
//! Run: `cargo run --release --example quickstart`

use woss::bench::{execute, RunSpec, SystemKind};
use woss::hints::TagSet;
use woss::sim::{Calib, Cluster, DiskKind, SimTime};
use woss::storage::{standard_deployment, NodeId, StorageModel};
use woss::workloads;

fn main() {
    println!("== 1. deploy WOSS over a simulated 8-node cluster ==");
    let calib = Calib::cluster();
    let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
    let mut store = standard_deployment(&cluster, /*woss=*/ true, /*ram=*/ true, 42);

    println!("== 2. top-down: hints via plain extended attributes ==");
    // The workflow runtime tags the output path *before* the task
    // writes it — no API beyond setxattr.
    store
        .set_xattr(&mut cluster, NodeId(3), "/data/stage1.out", "DP", "local", SimTime::ZERO)
        .unwrap();
    let done = store
        .write_file(
            &mut cluster,
            NodeId(3),
            "/data/stage1.out",
            64 << 20,
            &TagSet::new(),
            SimTime::ZERO,
        )
        .unwrap();
    println!("   wrote 64 MB tagged DP=local in {done}");

    println!("== 3. bottom-up: the storage exposes data location ==");
    let (loc, _) = store
        .get_xattr(&mut cluster, NodeId(0), "/data/stage1.out", "location", done)
        .unwrap();
    println!("   getxattr(location) -> {:?}  (the scheduler reads this)", loc.unwrap());

    let tags = TagSet::from_pairs([("Replication", "4"), ("RepSmntc", "optimistic")]);
    store
        .write_file(&mut cluster, NodeId(2), "/data/shared.db", 32 << 20, &tags, done)
        .unwrap();
    println!(
        "   broadcast file replicated to: {:?}",
        store.locations("/data/shared.db")
    );

    println!("== 4. the payoff: pipeline pattern, tagged vs untagged ==");
    let woss = execute(
        &RunSpec::cluster(SystemKind::WossRam, 1),
        &workloads::pipeline(19, 1.0, true),
    );
    let dss = execute(
        &RunSpec::cluster(SystemKind::DssRam, 1),
        &workloads::pipeline(19, 1.0, false),
    );
    println!(
        "   WOSS {:.1}s vs DSS {:.1}s (workflow time) -> {:.1}x from two xattr calls per file",
        woss.workflow_span(),
        dss.workflow_span(),
        dss.workflow_span() / woss.workflow_span()
    );
    println!(
        "   locality: WOSS served {:.0}% of bytes node-locally (DSS: {:.0}%)",
        woss.metrics.locality() * 100.0,
        dss.metrics.locality() * 100.0
    );
}
