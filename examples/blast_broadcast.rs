//! BLAST scenario: tuning the broadcast pattern's replication factor.
//!
//! The paper's Table 4 shows the trade-off the `Replication` hint
//! exposes: more replicas make the stage-in slower but the parallel
//! search faster, with a sweet spot well below full replication. This
//! example sweeps the factor on the simulated cluster and prints the
//! breakdown, then shows the same hint steering the *live* store.
//!
//! Run: `cargo run --release --example blast_broadcast`

use woss::bench::{execute, RunSpec, SystemKind};
use woss::hints::TagSet;
use woss::live::LiveStore;
use woss::storage::NodeId;
use woss::util::table::Table;
use woss::workloads::Blast;

fn main() {
    println!("== simulated: replication sweep (19 workers, 1.8 GB database) ==\n");
    let mut table = Table::new("BLAST breakdown vs replication")
        .header(["config", "stage-in (s)", "all tasks (s)", "total (s)"]);
    for (label, sys, rep) in [
        ("NFS", SystemKind::Nfs, None),
        ("DSS", SystemKind::DssRam, None),
        ("WOSS r2", SystemKind::WossRam, Some(2)),
        ("WOSS r4", SystemKind::WossRam, Some(4)),
        ("WOSS r8", SystemKind::WossRam, Some(8)),
        ("WOSS r16", SystemKind::WossRam, Some(16)),
    ] {
        let blast = Blast {
            db_replication: rep,
            ..Default::default()
        };
        let r = execute(&RunSpec::cluster(sys, 7), &blast.build());
        table.row([
            label.to_string(),
            format!("{:.0}", r.stage_end("stageIn")),
            format!("{:.0}", r.stage_end("blast")),
            format!("{:.0}", r.makespan),
        ]);
    }
    println!("{}", table.render());

    println!("== live: the same hint moves real replicas ==");
    let store = LiveStore::woss(6);
    let db = vec![0xDBu8; 2 << 20];
    let tags = TagSet::from_pairs([("Replication", "4"), ("RepSmntc", "optimistic")]);
    store.write_file(NodeId(0), "/blast/db", &db, &tags).unwrap();
    // Optimistic semantics returned after the primary copy; the barrier
    // waits for the background pool so the locality numbers below are
    // deterministic.
    store.flush_replication();
    println!(
        "   2 MB database written with Replication=4 -> holders {:?}",
        store.locations("/blast/db")
    );
    println!(
        "   replication_state attribute: {:?}",
        store.get_xattr("/blast/db", "replication_state")
    );
    // Workers on replica holders read without touching the network.
    for holder in store.locations("/blast/db").into_iter().take(3) {
        store.read_file(holder, "/blast/db").unwrap();
    }
    println!(
        "   after 3 worker reads on holders: {} local / {} remote chunk reads",
        store.local_reads.load(std::sync::atomic::Ordering::Relaxed),
        store.remote_reads.load(std::sync::atomic::Ordering::Relaxed)
    );
}
