//! modFTDock scenario: mixing broadcast, reduce, and pipeline hints.
//!
//! One workflow exercising all three patterns at once (paper Figure 9):
//! the database broadcast to every dock task, each stream's dock outputs
//! collocated for the merge (reduce), and the merge output placed
//! locally for the score stage (pipeline). Shows per-pattern hints and
//! the Swift-personality overhead that caused the paper's fig11 anomaly.
//!
//! Run: `cargo run --release --example modftdock_pipeline`

use woss::bench::{execute, RunSpec, SystemKind};
use woss::workloads::ModFtDock;

fn main() {
    println!("== modFTDock on the simulated cluster ==\n");
    for (label, sys, hints) in [
        ("NFS", SystemKind::Nfs, false),
        ("DSS", SystemKind::DssRam, false),
        ("WOSS", SystemKind::WossRam, true),
    ] {
        let dock = ModFtDock {
            hints,
            ..Default::default()
        };
        let r = execute(&RunSpec::cluster(sys, 11), &dock.build());
        println!(
            "   {label:5} total {:6.1}s | dock ends {:6.1}s | merge ends {:6.1}s | locality {:>3.0}%",
            r.makespan,
            r.stage_end("dock"),
            r.stage_end("merge"),
            r.metrics.locality() * 100.0
        );
    }

    println!("\n== the fig11 anomaly: Swift launches a task per tag op ==\n");
    for swift_ms in [0.0, 20.0, 50.0, 100.0] {
        let mut spec = RunSpec::cluster(SystemKind::WossRam, 11);
        spec.calib.swift_tag_task_ms = swift_ms;
        let dock = ModFtDock::default();
        let r = execute(&spec, &dock.build());
        println!(
            "   swift tag-op cost {swift_ms:>5.1} ms  ->  total {:6.1}s",
            r.makespan
        );
    }
    println!("\n(pyFlow keeps tag ops in-process: 0 ms row. The paper's BG/P");
    println!(" regression is the 50 ms row scaled to hundreds of streams.)");
}
