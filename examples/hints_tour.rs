//! A tour of every implemented hint (paper Table 3) + extensibility.
//!
//! Walks each hint through the live store so the effect is visible in
//! actual chunk placement, then registers a brand-new optimization
//! module at runtime — the paper's extensibility claim ("decide the
//! key-value pair, implement the callback, register it").
//!
//! Run: `cargo run --release --example hints_tour`

use woss::dispatch::{PlacementCtx, PlacementPolicy, Registry};
use woss::hints::TagSet;
use woss::live::LiveStore;
use woss::storage::NodeId;

fn main() {
    let store = LiveStore::woss(6);
    let blob = |n: usize| vec![0xA5u8; n];

    println!("== DP=local (pipeline pattern) ==");
    store
        .write_file(NodeId(4), "/t/local", &blob(800_000), &TagSet::from_pairs([("DP", "local")]))
        .unwrap();
    println!("   holders: {:?} (the writer)", store.locations("/t/local"));

    println!("== DP=collocation <group> (reduce pattern) ==");
    for i in 0..3 {
        store
            .write_file(
                NodeId(i),
                &format!("/t/part{i}"),
                &blob(400_000),
                &TagSet::from_pairs([("DP", "collocation mergeG")]),
            )
            .unwrap();
    }
    println!(
        "   three writers, one anchor: {:?} {:?} {:?}",
        store.locations("/t/part0"),
        store.locations("/t/part1"),
        store.locations("/t/part2")
    );

    println!("== DP=scatter <n> + BlockSize (scatter pattern) ==");
    store
        .write_file(
            NodeId(0),
            "/t/scatter",
            &blob(1_200_000),
            &TagSet::from_pairs([("DP", "scatter 1"), ("BlockSize", "200K")]),
        )
        .unwrap();
    println!(
        "   6 × 200 KB blocks round-robin: {:?}",
        store.locations("/t/scatter")
    );
    println!(
        "   chunk_location: {}",
        store.get_xattr("/t/scatter", "chunk_location").unwrap()
    );

    println!("== Replication=<n> (broadcast pattern) ==");
    store
        .write_file(
            NodeId(1),
            "/t/hot",
            &blob(500_000),
            &TagSet::from_pairs([("Replication", "3")]),
        )
        .unwrap();
    println!("   holders: {:?}", store.locations("/t/hot"));
    println!(
        "   replication_state: {:?}",
        store.get_xattr("/t/hot", "replication_state")
    );

    println!("== bottom-up reserved attributes ==");
    println!("   location:      {:?}", store.get_xattr("/t/hot", "location"));
    println!("   system_status: {:?}", store.get_xattr("/t/hot", "system_status"));

    println!("== hints are hints: malformed tags fall back safely ==");
    store
        .write_file(
            NodeId(2),
            "/t/odd",
            &blob(300_000),
            &TagSet::from_pairs([("DP", "teleport to mars"), ("Replication", "lots")]),
        )
        .unwrap();
    println!(
        "   malformed DP/Replication -> default striping: {:?}",
        store.locations("/t/odd")
    );

    println!("== extensibility: register a new module at runtime ==");
    /// `Pin=<node>` — a 10-line policy a downstream developer might add.
    struct PinPolicy;
    impl PlacementPolicy for PinPolicy {
        fn name(&self) -> &'static str {
            "placement.pin"
        }
        fn place(&self, ctx: &mut PlacementCtx<'_>, _idx: u64, bytes: u64) -> Option<NodeId> {
            let target = ctx.tags.get("Pin")?.parse().ok().map(NodeId)?;
            ctx.fits(target, bytes).then_some(target)
        }
    }
    let mut registry = Registry::woss();
    registry.register_placement(Box::new(PinPolicy));
    let store2 = LiveStore::new(registry, 6, u64::MAX / 2);
    store2
        .write_file(NodeId(0), "/t/pinned", &blob(300_000), &TagSet::from_pairs([("Pin", "5")]))
        .unwrap();
    println!(
        "   new `Pin=5` hint honored by the fresh module: {:?}",
        store2.locations("/t/pinned")
    );

    println!("== Lifetime + Consumers (scratch reclamation) ==");
    // A cache-enabled, lifetime-enforcing deployment: the intermediate
    // is declared dead after two reads and the store reclaims it.
    let store3 = LiveStore::woss_with(
        4,
        woss::live::LiveTuning {
            cache_bytes: Some(8 << 20),
            lifetime: true,
            ..woss::live::LiveTuning::default()
        },
    );
    store3
        .write_file(
            NodeId(0),
            "/t/scratch",
            &blob(400_000),
            &TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch"), ("Consumers", "2")]),
        )
        .unwrap();
    println!(
        "   consumers_left after write: {:?}",
        store3.get_xattr("/t/scratch", "consumers_left")
    );
    store3.read_file(NodeId(1), "/t/scratch").unwrap();
    println!(
        "   after 1st read:            {:?}",
        store3.get_xattr("/t/scratch", "consumers_left")
    );
    store3.read_file(NodeId(2), "/t/scratch").unwrap();
    println!(
        "   after 2nd (last) read:     reclaimed -> read now fails: {}",
        store3.read_file(NodeId(1), "/t/scratch").is_err()
    );

    println!("== Pattern=pipeline (cache prefetch) ==");
    store3
        .write_file(
            NodeId(0),
            "/t/stage_out",
            &blob(600_000),
            &TagSet::from_pairs([("DP", "local"), ("Pattern", "pipeline")]),
        )
        .unwrap();
    let queued = store3.prefetch(NodeId(3), "/t/stage_out").unwrap();
    store3.flush_replication();
    println!(
        "   {queued} chunks promoted into n3's cache; cache_state: {:?}",
        store3.get_xattr("/t/stage_out", "cache_state")
    );
    store3.read_file(NodeId(3), "/t/stage_out").unwrap();
    println!(
        "   consumer read served locally: {} local / {} remote chunk reads on this store",
        store3
            .local_reads
            .load(std::sync::atomic::Ordering::Relaxed),
        store3
            .remote_reads
            .load(std::sync::atomic::Ordering::Relaxed)
    );
}
