//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Runs a Montage-shaped workflow (≈100 tasks, ~40 MB of real bytes)
//! through the LIVE engine: the rust coordinator schedules tasks
//! location-aware over an in-process WOSS deployment holding actual
//! chunk bytes, and every task body executes the AOT-compiled JAX/Pallas
//! kernels through PJRT (stage transform, 8-way reduce merge). Data
//! integrity is verified end-to-end with the checksum kernel, and the
//! run is compared against the DSS baseline (hints off) on the same
//! workload.
//!
//! Requires `make artifacts` first.
//!
//! Run: `cargo run --release --example montage_e2e`

use woss::live::{LiveEngine, LiveStore};
use woss::workloads::Montage;

fn main() -> anyhow::Result<()> {
    let workload = |hints: bool| Montage {
        inputs: 16,
        hints,
        scale: 0.05,
    };

    println!("== live Montage over WOSS (8 nodes, 8 workers) ==");
    let woss = LiveEngine::new(LiveStore::woss(8), 8)?;
    let wf = workload(true).build();
    println!(
        "   workflow: {} tasks, {} stages, {:.1} MB to write",
        wf.tasks.len(),
        wf.stages().len(),
        wf.bytes_written() as f64 / 1048576.0
    );
    let r_woss = woss.run(&wf)?;
    let verified = woss.verify(&r_woss)?;
    report("WOSS", &r_woss);
    println!("   integrity: {verified} files re-read + checksum-verified via the PJRT kernel");

    println!("== same workload over DSS (hints ignored) ==");
    let dss = LiveEngine::new(LiveStore::dss(8), 8)?;
    let r_dss = dss.run(&workload(false).build())?;
    report("DSS", &r_dss);

    println!("== comparison ==");
    println!(
        "   locality: WOSS {:.0}% vs DSS {:.0}% of chunk reads served node-locally",
        r_woss.locality() * 100.0,
        r_dss.locality() * 100.0
    );
    anyhow::ensure!(
        r_woss.locality() > r_dss.locality(),
        "cross-layer hints must improve locality"
    );
    println!("   -> the cross-layer channel changed real data placement, end to end.");
    Ok(())
}

fn report(label: &str, r: &woss::live::LiveReport) {
    println!(
        "   {label}: {} tasks in {:.2}s | {:.1} MB written, {:.1} MB read ({:.0} MB/s) | kernels: {:?}",
        r.tasks,
        r.elapsed_secs,
        r.bytes_written as f64 / 1048576.0,
        r.bytes_read as f64 / 1048576.0,
        r.throughput_mbps(),
        r.kernel_execs
    );
}
