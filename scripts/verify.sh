#!/usr/bin/env bash
# Tier-1 verification plus the documentation gate, in one command:
#   scripts/verify.sh
# Runs from any working directory.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt component unavailable in this toolchain; skipping"
fi

echo "== cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    # Lints lib + bins (the shipped surface). Widening to --all-targets
    # also lints tests/benches — do that in a dedicated sweep so any
    # style lints it surfaces in test code can be fixed in the same
    # change rather than leaving the gate red.
    cargo clippy -- -D warnings
else
    echo "clippy component unavailable in this toolchain; skipping"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The live suite again, against both chunk backends. LIVE_BACKEND is the
# LiveTuning::default() hook: `disk` reroutes every default-tuned live
# store through the file-backed spill tier. WOSS_DATA_DIR roots the
# stores' auto-created data directories in a tempdir we can audit: a
# clean run leaves it empty (stores remove their own directories on
# drop, deletes/reclaims unlink chunk files), so anything left behind is
# a leak and fails the gate.
echo "== live suite × chunk-backend matrix (LIVE_BACKEND=mem|disk) =="
for backend in mem disk; do
    tmpdir="$(mktemp -d)"
    echo "-- LIVE_BACKEND=$backend --"
    LIVE_BACKEND="$backend" WOSS_DATA_DIR="$tmpdir" cargo test -q --lib live::
    LIVE_BACKEND="$backend" WOSS_DATA_DIR="$tmpdir" cargo test -q \
        --test live_cache --test live_concurrency --test live_stack \
        --test backend_equivalence
    stray="$(find "$tmpdir" -type f | head -20)"
    if [ -n "$stray" ]; then
        echo "FAIL: the $backend run left stray files under $tmpdir:"
        echo "$stray"
        exit 1
    fi
    rm -rf "$tmpdir"
done

echo "== cargo test --doc (HINTS.md's mirrored doctests) =="
# The doc examples in docs/HINTS.md are mirrored as rustdoc doctests
# (hints/tagset.rs, hints/mod.rs); this gate keeps document and
# implementation honest together.
cargo test --doc -q

echo "== cargo doc --no-deps -D warnings (missing_docs + broken links) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "verify.sh: all gates green"
