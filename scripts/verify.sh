#!/usr/bin/env bash
# Tier-1 verification plus the documentation gate, in one command:
#   scripts/verify.sh
# Runs from any working directory.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (missing_docs must be clean) =="
doc_log="$(mktemp)"
if ! cargo doc --no-deps 2>&1 | tee "$doc_log"; then
    rm -f "$doc_log"
    exit 1
fi
if grep -E "missing documentation" "$doc_log" >/dev/null; then
    echo "error: cargo doc reported missing_docs warnings (see above)" >&2
    rm -f "$doc_log"
    exit 1
fi
rm -f "$doc_log"

echo "verify.sh: all gates green"
