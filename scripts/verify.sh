#!/usr/bin/env bash
# Tier-1 verification plus the documentation gate, in one command:
#   scripts/verify.sh
# Runs from any working directory.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt component unavailable in this toolchain; skipping"
fi

echo "== cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    # Lints lib + bins (the shipped surface). Widening to --all-targets
    # also lints tests/benches — do that in a dedicated sweep so any
    # style lints it surfaces in test code can be fixed in the same
    # change rather than leaving the gate red.
    cargo clippy -- -D warnings
else
    echo "clippy component unavailable in this toolchain; skipping"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo test --doc (HINTS.md's mirrored doctests) =="
# The doc examples in docs/HINTS.md are mirrored as rustdoc doctests
# (hints/tagset.rs, hints/mod.rs); this gate keeps document and
# implementation honest together.
cargo test --doc -q

echo "== cargo doc --no-deps -D warnings (missing_docs + broken links) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "verify.sh: all gates green"
