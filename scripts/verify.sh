#!/usr/bin/env bash
# Tier-1 verification plus the documentation gate, in one command:
#   scripts/verify.sh
# Runs from any working directory.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt component unavailable in this toolchain; skipping"
fi

echo "== cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    # Lints lib + bins (the shipped surface). Widening to --all-targets
    # also lints tests/benches — do that in a dedicated sweep so any
    # style lints it surfaces in test code can be fixed in the same
    # change rather than leaving the gate red.
    cargo clippy -- -D warnings
else
    echo "clippy component unavailable in this toolchain; skipping"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The live suite again, against every chunk backend. LIVE_BACKEND is
# the LiveTuning::default() hook: `disk` reroutes every default-tuned
# live store through the file-backed spill tier, `seg` through the
# packed segment log. WOSS_DATA_DIR roots the stores' auto-created data
# directories in a tempdir we can audit: a clean run leaves it empty
# (stores remove their own directories on drop, deletes/reclaims unlink
# chunk files and compact dead segment bytes), so anything left behind
# is a leak and fails the gate — a surviving seg-*.log after the
# delete-everything tests is called out by name.
echo "== live suite × chunk-backend matrix (LIVE_BACKEND=mem|disk|seg) =="
for backend in mem disk seg; do
    tmpdir="$(mktemp -d)"
    echo "-- LIVE_BACKEND=$backend --"
    LIVE_BACKEND="$backend" WOSS_DATA_DIR="$tmpdir" cargo test -q --lib live::
    LIVE_BACKEND="$backend" WOSS_DATA_DIR="$tmpdir" cargo test -q \
        --test live_cache --test live_concurrency --test live_stack \
        --test backend_equivalence --test live_recovery
    stray_segs="$(find "$tmpdir" -type f -name 'seg-*.log*' | head -5)"
    if [ -n "$stray_segs" ]; then
        echo "FAIL: the $backend run left stray segment files after delete:"
        echo "$stray_segs"
        exit 1
    fi
    stray="$(find "$tmpdir" -type f | head -20)"
    if [ -n "$stray" ]; then
        echo "FAIL: the $backend run left stray files under $tmpdir:"
        echo "$stray"
        exit 1
    fi
    rm -rf "$tmpdir"
done

# Restart leg: both persistent tiers must survive process death. Run a
# live workload crash-style (no clean shutdown — the process just
# exits), reopen the same data dir in a fresh process and verify every
# recorded fingerprint reads back identical (journal-salvage path; on
# seg this also replays the segment logs); the reopen shuts down clean,
# so a second reopen exercises the snapshot path and must verify the
# same fingerprints again. The stray-file gate above stays in force:
# this leg uses its own directory and removes it.
woss="./target/release/woss"
for backend in disk seg; do
    echo "== $backend restart leg (crash salvage + snapshot reopen) =="
    restart_dir="$(mktemp -d)"
    "$woss" live --workload pipeline --nodes 4 --workers 4 \
        --backend "$backend" --data-dir "$restart_dir/store" \
        --fingerprint-file "$restart_dir/fingerprints.txt"
    "$woss" live --reopen --data-dir "$restart_dir/store" \
        --fingerprint-file "$restart_dir/fingerprints.txt" \
        | tee "$restart_dir/reopen1.out"
    grep -q "crash (journal salvage)" "$restart_dir/reopen1.out" \
        || { echo "FAIL: first $backend reopen should take the crash-salvage path"; exit 1; }
    "$woss" live --reopen --data-dir "$restart_dir/store" \
        --fingerprint-file "$restart_dir/fingerprints.txt" \
        | tee "$restart_dir/reopen2.out"
    grep -q "after a clean shutdown" "$restart_dir/reopen2.out" \
        || { echo "FAIL: second $backend reopen should take the snapshot path"; exit 1; }
    rm -rf "$restart_dir"
done

# Hostile-scenario smoke: fast scenarios on every chunk backend with a
# fixed seed — small_file_flood rides along to race the disk and seg
# backends on a tiny-chunk ingest and audit the packed layout. Each run
# ends in a full bottom-up audit and the binary exits non-zero on a
# dirty one, so this leg passing means fingerprints, usage accounting,
# and the on-disk chunk population all reconciled.
echo "== scenario smoke (metadata_storm,small_file_flood,kill_recover × mem|disk|seg, seed 7) =="
scn_dir="$(mktemp -d)"
"$woss" scenario metadata_storm,small_file_flood,kill_recover --quick --seed 7 --backend mem
"$woss" scenario metadata_storm,small_file_flood,kill_recover --quick --seed 7 \
    --backend disk --data-dir "$scn_dir/smoke"
"$woss" scenario metadata_storm,small_file_flood,kill_recover --quick --seed 7 \
    --backend seg --data-dir "$scn_dir/smoke-seg"
# Same schedules again with the I/O pool fanned out: the pipelined data
# path must close the same audits clean at io_workers=4.
"$woss" scenario metadata_storm,small_file_flood,kill_recover --quick --seed 7 \
    --backend disk --data-dir "$scn_dir/smoke4" --io-workers 4
"$woss" scenario metadata_storm,small_file_flood,kill_recover --quick --seed 7 \
    --backend seg --data-dir "$scn_dir/smoke4-seg" --io-workers 4
rm -rf "$scn_dir"

# Adaptive-placement leg: hot_skew dual-runs the identical seeded
# workload with the load-feedback plane off and on, in both primary
# modes. Both invocations must close clean audits; the off-mode run
# doubles as a check that collecting the signals alone never perturbs
# the static decision path.
echo "== adaptive placement (hot_skew --adaptive on|off, seed 7) =="
adp_dir="$(mktemp -d)"
"$woss" scenario hot_skew --quick --seed 7 --adaptive off
"$woss" scenario hot_skew --quick --seed 7 --adaptive on
"$woss" scenario hot_skew,tenant_pressure --quick --seed 7 \
    --backend disk --data-dir "$adp_dir/adp" --adaptive on
rm -rf "$adp_dir"

# Pipeline-equivalence leg: the I/O pool must change scheduling, never
# semantics. The same single-worker workload runs on each persistent
# backend at --io-workers 1 (the serial pre-pool data path) and 4 (real
# overlap), and the recorded output fingerprints must be
# byte-identical. The cache+lifetime runs also compare the reclamation
# line (scratch files reclaimed, bytes returned), and the cache-less
# pipeline run compares the locality line (local/remote chunk-read
# counts) — prefetch is a background race by design, so locality is
# only compared where no cache tier is in play.
for be in disk seg; do
    echo "== io-workers equivalence (--io-workers 1 vs 4, $be matrix) =="
    io_dir="$(mktemp -d)"
    for wl in pipeline montage; do
        for iow in 1 4; do
            "$woss" live --workload "$wl" --nodes 4 --workers 1 \
                --backend "$be" --data-dir "$io_dir/$wl-$iow" \
                --cache-mb 2 --lifetime --io-workers "$iow" \
                --fingerprint-file "$io_dir/$wl-$iow.fp" \
                > "$io_dir/$wl-$iow.out"
        done
        cmp "$io_dir/$wl-1.fp" "$io_dir/$wl-4.fp" \
            || { echo "FAIL: $be $wl fingerprints diverge between --io-workers 1 and 4"; exit 1; }
        a="$(grep '  lifetime:' "$io_dir/$wl-1.out")"
        b="$(grep '  lifetime:' "$io_dir/$wl-4.out")"
        [ "$a" = "$b" ] \
            || { echo "FAIL: $be $wl reclamation diverges: '$a' vs '$b'"; exit 1; }
    done
    for iow in 1 4; do
        "$woss" live --workload pipeline --nodes 4 --workers 1 \
            --backend "$be" --data-dir "$io_dir/plain-$iow" \
            --io-workers "$iow" \
            --fingerprint-file "$io_dir/plain-$iow.fp" \
            > "$io_dir/plain-$iow.out"
    done
    cmp "$io_dir/plain-1.fp" "$io_dir/plain-4.fp" \
        || { echo "FAIL: $be plain fingerprints diverge between --io-workers 1 and 4"; exit 1; }
    a="$(grep '  locality:' "$io_dir/plain-1.out")"
    b="$(grep '  locality:' "$io_dir/plain-4.out")"
    [ "$a" = "$b" ] \
        || { echo "FAIL: $be locality diverges between --io-workers 1 and 4: '$a' vs '$b'"; exit 1; }
    rm -rf "$io_dir"
done

# Cluster leg: the carved service boundary, as real processes. Three
# `woss noded` daemons and a `woss managerd` over Unix sockets serve the
# same workloads `woss live` runs in-process, and the recorded output
# fingerprints must be byte-identical across the transport — the wire
# protocol is a transport, never a semantics knob. `--clean-shutdown`
# on the wire run doubles as the managerd termination path (a Shutdown
# request stops its serve loop). The socket scenario smoke then drives
# the same daemons as scenario children: kill_recover SIGKILLs a real
# noded mid-workflow and its restart salvages via `noded --reopen`.
echo "== cluster leg (managerd + 3 noded over Unix sockets) =="
clu_dir="$(mktemp -d)"
clu_pids=""
cleanup_cluster() { [ -n "$clu_pids" ] && kill $clu_pids 2>/dev/null || true; }
trap cleanup_cluster EXIT
for wl in pipeline montage; do
    d="$clu_dir/$wl"
    mkdir -p "$d"
    for i in 0 1 2; do
        "$woss" noded --listen "unix:$d/n$i.sock" --backend mem \
            > "$d/n$i.log" 2>&1 &
        clu_pids="$clu_pids $!"
    done
    "$woss" managerd --listen "unix:$d/mgr.sock" \
        --nodes "unix:$d/n0.sock,unix:$d/n1.sock,unix:$d/n2.sock" \
        > "$d/mgr.log" 2>&1 &
    clu_pids="$clu_pids $!"
    "$woss" live --workload "$wl" --nodes 3 --workers 4 \
        --fingerprint-file "$d/local.fp" > /dev/null
    "$woss" live --connect "unix:$d/mgr.sock" --workload "$wl" --workers 4 \
        --clean-shutdown --fingerprint-file "$d/wire.fp" > /dev/null
    cmp "$d/local.fp" "$d/wire.fp" \
        || { echo "FAIL: $wl fingerprints diverge between in-process and socket transports"; exit 1; }
done
echo "== scenario smoke over sockets (kill_recover --transport socket) =="
"$woss" scenario kill_recover --quick --seed 7 --transport socket
"$woss" scenario kill_recover --quick --seed 7 --transport socket \
    --backend seg --data-dir "$clu_dir/scn-seg"
cleanup_cluster
clu_pids=""
rm -rf "$clu_dir"

# Tracked perf trajectory: regenerate both bench documents and validate
# them against their schemas. A missing, unparseable, or schema-drifted
# document fails the gate (bench-check is also what CI should run on the
# committed copies). The full-size kill_recover row now dual-runs its
# socket leg (real noded children of the woss binary itself), so the
# regenerated document carries a live `read_p99_ms_wire` column for
# bench-check's v3 gate.
echo "== bench trajectory (BENCH_scenarios.json / BENCH_live.json) =="
bench_dir="$(mktemp -d)"
"$woss" scenario all --seed 7 --backend disk --data-dir "$bench_dir/scn" \
    --json ../BENCH_scenarios.json
"$woss" experiment live --runs 2 --seed 7 --json ../BENCH_live.json
"$woss" bench-check --scenarios ../BENCH_scenarios.json --live ../BENCH_live.json
rm -rf "$bench_dir"

echo "== cargo test --doc (HINTS.md's mirrored doctests) =="
# The doc examples in docs/HINTS.md are mirrored as rustdoc doctests
# (hints/tagset.rs, hints/mod.rs); this gate keeps document and
# implementation honest together.
cargo test --doc -q

echo "== cargo doc --no-deps -D warnings (missing_docs + broken links) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "verify.sh: all gates green"
