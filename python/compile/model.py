"""L2: the workflow task compute graphs, composed from the L1 kernels.

These jitted functions are what actually gets lowered to HLO text and
executed by the rust coordinator's PJRT runtime. Python never runs on
the request path: ``aot.py`` lowers each entry point once at build time.

Entry points (all static shapes, f32):

* ``stage_transform(x, w, b)`` — one tile through the per-stage
  transform kernel (pipeline-pattern task body).
* ``stage_chain(x, w1, b1, w2, b2)`` — two chained transforms, fused by
  XLA into one executable (a two-stage pipeline body, used to validate
  that kernel composition lowers cleanly).
* ``reduce_merge(parts, weights)`` — 8-way weighted merge
  (reduce-pattern task body).
* ``checksum(x)`` — block fingerprint (integrity verification on the
  live data path).
"""

import jax
import jax.numpy as jnp

from .kernels import checksum as checksum_k
from .kernels import reduce_merge as reduce_k
from .kernels import stage_transform as stage_k
from .kernels.ref import TILE

K = reduce_k.K


def stage_transform(x, w, b):
    """One pipeline-stage transform over a tile."""
    return (stage_k.stage_transform(x, w, b),)


def stage_chain(x, w1, b1, w2, b2):
    """Two pipeline stages fused into one lowered computation."""
    y = stage_k.stage_transform(x, w1, b1)
    z = stage_k.stage_transform(y, w2, b2)
    return (z,)


def reduce_merge(parts, weights):
    """8-way reduce-pattern merge."""
    return (reduce_k.reduce_merge(parts, weights),)


def checksum(x):
    """Block fingerprint."""
    return (checksum_k.checksum(x),)


def entry_points():
    """(name, fn, example_args) for every AOT artifact."""
    tile = jax.ShapeDtypeStruct((TILE, TILE), jnp.float32)
    vec = jax.ShapeDtypeStruct((K,), jnp.float32)
    parts = jax.ShapeDtypeStruct((K, TILE, TILE), jnp.float32)
    return [
        ("stage_transform", stage_transform, (tile, tile, tile)),
        ("stage_chain", stage_chain, (tile, tile, tile, tile, tile)),
        ("reduce_merge", reduce_merge, (parts, vec)),
        ("checksum", checksum, (tile,)),
    ]
