"""AOT lowering: JAX → HLO text → ``artifacts/*.hlo.txt``.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with ``return_tuple=True``; the
rust side unwraps with ``to_tuple1()``.

Run once per build: ``make artifacts`` (no-op when inputs are older than
the outputs). Python is never on the request path.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    """Lower a jittable fn to XLA HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir",
        default="../artifacts",
        help="directory for the .hlo.txt artifacts",
    )
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    for name, fn, example_args in model.entry_points():
        text = to_hlo_text(fn, example_args)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
