"""L1 Pallas kernel: per-stage data transform.

``y = tanh(x @ w + b)`` on one 256×256 f32 tile.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper is a
storage paper with no GPU kernels to port, so the task-compute payload is
authored TPU-first: a 256×256 tile fits comfortably in VMEM (3 × 256 KiB
working set), the matmul maps onto the 128×128 MXU as a 2×2 macro-tile,
and the bias+tanh epilogue runs on the VPU. The kernel is lowered with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls — so correctness is validated through the interpret path and
TPU performance is *estimated* from the VMEM/MXU model in EXPERIMENTS.md
§Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = ref.TILE


def _kernel(x_ref, w_ref, b_ref, o_ref):
    # One fused VMEM-resident tile op: MXU matmul + VPU epilogue.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.tanh(acc + b_ref[...])


def stage_transform(x, w, b):
    """Pallas entry point; shapes ``(TILE, TILE)`` f32 throughout."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((TILE, TILE), jnp.float32),
        interpret=True,
    )(x, w, b)
