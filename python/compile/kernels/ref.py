"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests``) sweeps shapes/data with hypothesis and asserts
``assert_allclose`` between the Pallas (interpret=True) kernel and its
oracle. The rust runtime never sees these — they are correctness
anchors only.
"""

import jax.numpy as jnp

#: Side of the square data tile every kernel operates on. 256×256 f32 =
#: 256 KiB — one storage chunk, and a shape that tiles the TPU MXU
#: (128×128 systolic array) exactly 2×2.
TILE = 256


def stage_transform(x, w, b):
    """Reference for the per-stage data transform.

    ``y = tanh(x @ w + b)`` over one tile: the workflow-task compute
    analog (mProject/mDiff/dock all reduce to dense per-block math for
    our purposes), shaped to keep the MXU busy.
    """
    return jnp.tanh(x @ w + b)


def reduce_merge(parts, weights):
    """Reference for the reduce-pattern merge.

    Weighted accumulation of ``k`` tiles into one:
    ``out = sum_i weights[i] * parts[i]`` — the mAdd / merge analog.
    ``parts`` has shape ``(k, TILE, TILE)``, ``weights`` ``(k,)``.
    """
    return jnp.einsum("k,kij->ij", weights, parts)


def checksum(x):
    """Reference for the block fingerprint.

    A position-weighted sum reduced to a scalar; cheap VPU-style
    reduction used by the live engine to verify data integrity across
    the storage path. Returns shape ``(1, 1)``.
    """
    n = x.shape[0] * x.shape[1]
    coeff = (jnp.arange(n, dtype=x.dtype) % 64.0 + 1.0).reshape(x.shape)
    return jnp.sum(x * coeff).reshape(1, 1)
