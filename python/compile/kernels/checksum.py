"""L1 Pallas kernel: block fingerprint.

Position-weighted reduction of one tile to a scalar. The live engine
fingerprints every block it moves so the end-to-end example can verify
that data survived the storage path bit-exactly (in f32 tolerance).

TPU shaping: a pure VPU reduction — one VMEM-resident tile, elementwise
multiply with a compile-time coefficient pattern, full-tile sum.
``interpret=True`` for CPU-PJRT execution.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = ref.TILE


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    n = TILE * TILE
    coeff = (
        jnp.arange(n, dtype=jnp.float32).reshape(TILE, TILE) % 64.0 + 1.0
    )
    o_ref[...] = jnp.sum(x * coeff).reshape(1, 1)


def checksum(x):
    """Pallas entry point; ``(TILE, TILE)`` f32 → ``(1, 1)`` f32."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(x)
