"""L1 Pallas kernel: reduce-pattern merge.

Weighted accumulation of ``K`` input tiles into one output tile — the
compute analog of mAdd/merge tasks consuming collocated inputs.

TPU shaping: the grid iterates over the ``K`` input tiles; each grid step
streams one 256 KiB tile HBM→VMEM through the BlockSpec while a VMEM
accumulator (the output block, revisited every step) integrates it —
the canonical Pallas reduction schedule. ``interpret=True`` for CPU-PJRT
execution; see stage_transform.py for the rationale.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = ref.TILE
#: Number of tiles merged per kernel invocation. Larger merges are tree-
#: composed by the caller (L2/L3), keeping the kernel's VMEM footprint
#: fixed at 2 tiles + the weight vector.
K = 8


def _kernel(w_ref, x_ref, o_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += w_ref[k] * x_ref[0]


def reduce_merge(parts, weights):
    """Pallas entry point; ``parts``: ``(K, TILE, TILE)`` f32,
    ``weights``: ``(K,)`` f32 → ``(TILE, TILE)`` f32."""
    return pl.pallas_call(
        _kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((K,), lambda k: (0,)),
            pl.BlockSpec((1, TILE, TILE), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((TILE, TILE), jnp.float32),
        interpret=True,
    )(weights, parts)
