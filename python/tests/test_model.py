"""L2 composition + AOT lowering checks."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref

TILE = ref.TILE


def tiles(seed, n=1, scale=0.05):
    rng = np.random.default_rng(seed)
    out = [
        rng.standard_normal((TILE, TILE)).astype(np.float32) * scale
        for _ in range(n)
    ]
    return out if n > 1 else out[0]


def test_stage_chain_equals_two_transforms():
    x, w1, b1, w2, b2 = tiles(5, 5)
    (chained,) = model.stage_chain(x, w1, b1, w2, b2)
    step1 = ref.stage_transform(x, w1, b1)
    step2 = ref.stage_transform(step1, w2, b2)
    assert_allclose(np.asarray(chained), np.asarray(step2), rtol=1e-5, atol=1e-5)


def test_entry_points_cover_all_artifacts():
    names = [name for name, _, _ in model.entry_points()]
    assert names == ["stage_transform", "stage_chain", "reduce_merge", "checksum"]


def test_every_entry_point_lowers_to_hlo_text():
    for name, fn, example_args in model.entry_points():
        text = aot.to_hlo_text(fn, example_args)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ROOT" in text, f"{name}: no root instruction"
        # Interpret-mode pallas must lower to plain HLO — a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        assert "mosaic" not in text.lower(), f"{name}: Mosaic custom-call leaked"


def test_lowered_outputs_are_tuples():
    # rust unwraps with to_tuple1(): every entry point returns a 1-tuple.
    for _, fn, example_args in model.entry_points():
        import jax

        out_tree = jax.eval_shape(fn, *example_args)
        assert isinstance(out_tree, tuple) and len(out_tree) == 1


def test_checksum_linear_in_input():
    x = tiles(9)
    (c1,) = model.checksum(x)
    (c2,) = model.checksum(2.0 * x)
    assert_allclose(np.asarray(c2), 2.0 * np.asarray(c1), rtol=1e-5)


def test_stage_transform_bounded():
    x, w, b = tiles(2, 3, scale=10.0)
    (y,) = model.stage_transform(x, w, b)
    arr = np.asarray(y)
    assert np.all(arr <= 1.0) and np.all(arr >= -1.0), "tanh range"
    assert arr.dtype == np.float32
