"""Kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Hypothesis sweeps the data distributions; shapes are the kernels' static
tile shapes (AOT artifacts are compiled for fixed shapes), with a padded
wrapper test covering ragged logical sizes the way the rust runtime pads
real chunks.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import checksum as checksum_k
from compile.kernels import reduce_merge as reduce_k
from compile.kernels import ref
from compile.kernels import stage_transform as stage_k

TILE = ref.TILE
K = reduce_k.K


def rng_tile(seed, scale=1.0, shape=(TILE, TILE)):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape, dtype=np.float32) * scale).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.01, 0.1, 1.0]))
def test_stage_transform_matches_ref(seed, scale):
    x = rng_tile(seed, scale)
    w = rng_tile(seed + 1, 0.05)
    b = rng_tile(seed + 2, 0.1)
    got = stage_k.stage_transform(x, w, b)
    want = ref.stage_transform(x, w, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reduce_merge_matches_ref(seed):
    rng = np.random.default_rng(seed)
    parts = rng.standard_normal((K, TILE, TILE)).astype(np.float32)
    weights = rng.standard_normal(K).astype(np.float32)
    got = reduce_k.reduce_merge(parts, weights)
    want = ref.reduce_merge(parts, weights)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_checksum_matches_ref(seed):
    x = rng_tile(seed)
    got = checksum_k.checksum(x)
    want = ref.checksum(x)
    assert got.shape == (1, 1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_checksum_detects_corruption():
    x = rng_tile(7)
    a = float(np.asarray(checksum_k.checksum(x))[0, 0])
    x2 = x.copy()
    x2[13, 200] += 1.0
    b = float(np.asarray(checksum_k.checksum(x2))[0, 0])
    assert a != b, "single-element corruption must change the fingerprint"


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(1, TILE),
    w=st.integers(1, TILE),
)
def test_padded_ragged_blocks(seed, h, w):
    """Ragged logical blocks are zero-padded to the tile, as the rust
    runtime does for the last chunk of a file; the transform of the
    padded region must match the oracle on the whole padded tile."""
    rng = np.random.default_rng(seed)
    ragged = rng.standard_normal((h, w)).astype(np.float32)
    x = np.zeros((TILE, TILE), dtype=np.float32)
    x[:h, :w] = ragged
    wmat = rng_tile(seed + 1, 0.05)
    b = np.zeros((TILE, TILE), dtype=np.float32)
    got = stage_k.stage_transform(x, wmat, b)
    want = ref.stage_transform(x, wmat, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_reduce_merge_zero_weights_is_zero():
    parts = np.ones((K, TILE, TILE), dtype=np.float32)
    weights = np.zeros(K, dtype=np.float32)
    out = np.asarray(reduce_k.reduce_merge(parts, weights))
    assert np.all(out == 0.0)


def test_reduce_merge_identity_selects_part():
    rng = np.random.default_rng(3)
    parts = rng.standard_normal((K, TILE, TILE)).astype(np.float32)
    weights = np.zeros(K, dtype=np.float32)
    weights[3] = 1.0
    out = np.asarray(reduce_k.reduce_merge(parts, weights))
    assert_allclose(out, parts[3], rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtypes_stable(dtype):
    x = jnp.asarray(rng_tile(11), dtype=dtype)
    w = jnp.asarray(rng_tile(12, 0.05), dtype=dtype)
    b = jnp.asarray(rng_tile(13, 0.1), dtype=dtype)
    out = stage_k.stage_transform(x, w, b)
    assert out.dtype == dtype
    assert bool(jnp.all(jnp.isfinite(out)))
